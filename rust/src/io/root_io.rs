//! ROOT IO baseline serializer (§2.2 / §3.10 comparison target).
//!
//! A faithful stand-in for the generic serialization work that ROOT I/O
//! performs and that TeraAgent IO deliberately avoids. For every message it
//! really executes the four costs from the paper's observations:
//!
//! 1. **Pointer deduplication** — a map of already-written object ids;
//!    repeated references become back-references, and deserialization
//!    re-links them to a single instance.
//! 2. **Self-describing schema** — each message carries class descriptors
//!    (names, field names, type tags, schema version), and every field
//!    value is preceded by a type tag that is checked on read (schema
//!    evolution hook).
//! 3. **Endianness normalization** — all multi-byte values are converted
//!    to big-endian wire order on write and back on read, regardless of
//!    host order (ROOT's portable streaming).
//! 4. **Allocate-per-object deserialization** — reading builds every agent
//!    and behavior vector as a fresh heap allocation; there is no
//!    zero-copy path.
//!
//! The point is an honest *relative* comparison: both serializers move the
//! same logical agent payload; this one pays the generic machinery.

use crate::core::agent::{Agent, AgentBatch, AgentKind, Behavior, CellType, SirState};
use crate::core::ids::{AgentPointer, GlobalId, LocalId};
use crate::util::Vec3;
use std::collections::HashMap;

/// Wire type tags (checked on every field read).
mod tag {
    pub const U8: u8 = 1;
    pub const U16: u8 = 2;
    pub const U32: u8 = 3;
    pub const U64: u8 = 4;
    pub const F64: u8 = 5;
    pub const OBJ: u8 = 6;
    pub const BACKREF: u8 = 7;
    pub const NULL: u8 = 8;
    pub const VEC: u8 = 9;
}

const SCHEMA_VERSION: u16 = 4;
const MESSAGE_MAGIC: u32 = 0x524F_4F54; // "ROOT"

#[derive(Debug, PartialEq, Eq)]
pub enum RootError {
    Truncated,
    BadMagic,
    TypeMismatch { expected: u8, got: u8 },
    UnknownClass(String),
    BadBackref(u32),
}

impl std::fmt::Display for RootError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self:?}")
    }
}

impl std::error::Error for RootError {}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

struct Writer {
    out: Vec<u8>,
    /// Pointer-dedup table: object id -> stream index.
    seen: HashMap<GlobalId, u32>,
    next_stream_index: u32,
    /// Streamer-info registry — ROOT resolves the streamer for every
    /// object by *class name* (`TClass::GetClass` + `TStreamerInfo`),
    /// which we model with a string-keyed lookup per streamed object.
    streamers: HashMap<String, u16>,
}

impl Writer {
    fn new() -> Self {
        let mut streamers = HashMap::new();
        for name in [
            "Agent",
            "Behavior::Growth",
            "Behavior::Divide",
            "Behavior::RandomWalk",
            "Behavior::Infection",
            "Behavior::TumorGrowth",
            "Behavior::Trade",
            "Behavior::Reputation",
        ] {
            streamers.insert(name.to_string(), SCHEMA_VERSION);
        }
        Writer { out: Vec::new(), seen: HashMap::new(), next_stream_index: 0, streamers }
    }

    /// Per-object streamer resolution (cost 2/4: reflection machinery).
    /// Returns the class version that is written ahead of the object.
    fn resolve_streamer(&self, class_name: &str) -> u16 {
        *self
            .streamers
            .get(class_name)
            .unwrap_or_else(|| panic!("no streamer for {class_name}"))
    }

    /// Begin a ROOT-style object record: byte-count placeholder + class
    /// version word (TBuffer::WriteVersion). Returns the patch position.
    fn begin_object(&mut self, class_name: &str) -> usize {
        let version = self.resolve_streamer(class_name);
        let pos = self.out.len();
        self.out.extend_from_slice(&0u32.to_be_bytes()); // byte count, patched
        self.out.extend_from_slice(&version.to_be_bytes());
        pos
    }

    /// Back-patch the byte count (TBuffer::SetByteCount).
    fn end_object(&mut self, pos: usize) {
        let count = (self.out.len() - pos - 4) as u32;
        self.out[pos..pos + 4].copy_from_slice(&count.to_be_bytes());
    }

    // All scalars go out big-endian (cost 3).
    fn u8(&mut self, v: u8) {
        self.out.push(tag::U8);
        self.out.push(v);
    }
    fn u16(&mut self, v: u16) {
        self.out.push(tag::U16);
        self.out.extend_from_slice(&v.to_be_bytes());
    }
    fn u32(&mut self, v: u32) {
        self.out.push(tag::U32);
        self.out.extend_from_slice(&v.to_be_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.out.push(tag::U64);
        self.out.extend_from_slice(&v.to_be_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.out.push(tag::F64);
        self.out.extend_from_slice(&v.to_bits().to_be_bytes());
    }
    fn raw_u32(&mut self, v: u32) {
        self.out.extend_from_slice(&v.to_be_bytes());
    }
    fn str(&mut self, s: &str) {
        self.raw_u32(s.len() as u32);
        self.out.extend_from_slice(s.as_bytes());
    }

    /// Self-describing class descriptor (cost 2).
    fn class_descriptor(&mut self, name: &str, fields: &[(&str, u8)]) {
        self.str(name);
        self.raw_u32(SCHEMA_VERSION as u32);
        self.raw_u32(fields.len() as u32);
        for (fname, ftag) in fields {
            self.str(fname);
            self.out.push(*ftag);
        }
    }
}

fn agent_fields() -> Vec<(&'static str, u8)> {
    vec![
        ("class_id", tag::U16),
        ("global_id", tag::U64),
        ("position", tag::VEC),
        ("diameter", tag::F64),
        ("payload", tag::VEC),
        ("behaviors", tag::VEC),
        ("neighbor_ref", tag::OBJ),
    ]
}

/// Serialize `(agent, behaviors)` pairs with the generic streamer. The
/// behavior slice rides alongside the agent header because agents no
/// longer own their behaviors — callers hand the arena slice (or an
/// empty one) per agent.
pub fn serialize<'a>(
    pairs: impl ExactSizeIterator<Item = (&'a Agent, &'a [Behavior])>,
) -> Vec<u8> {
    let mut w = Writer::new();
    w.raw_u32(MESSAGE_MAGIC);
    // Schema section: descriptors for every class that may appear.
    w.raw_u32(8); // descriptor count
    w.class_descriptor("Agent", &agent_fields());
    w.class_descriptor("Behavior::Growth", &[("rate", tag::F64), ("max_diameter", tag::F64)]);
    w.class_descriptor("Behavior::Divide", &[]);
    w.class_descriptor("Behavior::RandomWalk", &[("speed", tag::F64)]);
    w.class_descriptor(
        "Behavior::Infection",
        &[("radius", tag::F64), ("prob", tag::F64), ("recovery_iters", tag::U32)],
    );
    w.class_descriptor(
        "Behavior::TumorGrowth",
        &[("cycle_rate", tag::F64), ("max_diameter", tag::F64)],
    );
    w.class_descriptor(
        "Behavior::Trade",
        &[("radius", tag::F64), ("gain", tag::F64), ("cooldown", tag::U32)],
    );
    w.class_descriptor("Behavior::Reputation", &[("score", tag::F64), ("decay", tag::F64)]);
    w.raw_u32(pairs.len() as u32);
    for (a, bs) in pairs {
        write_agent(&mut w, a, bs);
    }
    w.out
}

fn write_agent(w: &mut Writer, a: &Agent, behaviors: &[Behavior]) {
    w.out.push(tag::OBJ);
    // Pointer-dedup registration (cost 1): agents are objects with identity.
    let stream_index = w.next_stream_index;
    w.next_stream_index += 1;
    if a.global_id.is_set() {
        w.seen.insert(a.global_id, stream_index);
    }
    // Streamer resolution + byte-count framing (costs 2/4).
    let obj = w.begin_object("Agent");
    w.u16(a.kind.class_id());
    w.u32(a.global_id.rank);
    w.u64(a.global_id.counter);
    w.f64(a.position.x);
    w.f64(a.position.y);
    w.f64(a.position.z);
    w.f64(a.diameter);
    match a.kind {
        AgentKind::Cell { cell_type, adhesion } => {
            w.u8(cell_type.code());
            w.f64(adhesion);
        }
        AgentKind::GrowingCell { volume, growth_rate, division_volume } => {
            w.f64(volume);
            w.f64(growth_rate);
            w.f64(division_volume);
        }
        AgentKind::Person { state, infected_for } => {
            w.u8(state.code());
            w.u32(infected_for);
        }
        AgentKind::TumorCell { cycle, quiescent } => {
            w.f64(cycle);
            w.u8(quiescent as u8);
        }
        AgentKind::Citizen { wealth, reputation } => {
            w.f64(wealth);
            w.f64(reputation);
        }
    }
    // Behavior vector: each element is an object with its own streamer
    // lookup and byte-count record (polymorphic container streaming).
    w.out.push(tag::VEC);
    w.raw_u32(behaviors.len() as u32);
    for b in behaviors {
        let bobj = w.begin_object(behavior_class_name(b));
        w.u16(b.class_id());
        match *b {
            Behavior::Growth { rate, max_diameter } => {
                w.f64(rate);
                w.f64(max_diameter);
            }
            Behavior::Divide => {}
            Behavior::RandomWalk { speed } => w.f64(speed),
            Behavior::Infection { radius, prob, recovery_iters } => {
                w.f64(radius);
                w.f64(prob);
                w.u32(recovery_iters);
            }
            Behavior::TumorGrowth { cycle_rate, max_diameter } => {
                w.f64(cycle_rate);
                w.f64(max_diameter);
            }
            Behavior::Trade { radius, gain, cooldown } => {
                w.f64(radius);
                w.f64(gain);
                w.u32(cooldown);
            }
            Behavior::Reputation { score, decay } => {
                w.f64(score);
                w.f64(decay);
            }
        }
        w.end_object(bobj);
    }
    // Agent reference with dedup: already-seen targets become back-refs.
    if a.neighbor_ref.is_null() {
        w.out.push(tag::NULL);
    } else if let Some(&idx) = w.seen.get(&a.neighbor_ref.target) {
        w.out.push(tag::BACKREF);
        w.raw_u32(idx);
    } else {
        // Forward reference: stream the id itself.
        w.out.push(tag::OBJ);
        w.u32(a.neighbor_ref.target.rank);
        w.u64(a.neighbor_ref.target.counter);
    }
    w.end_object(obj);
}

/// Class name of a behavior (the string ROOT would resolve streamers by).
fn behavior_class_name(b: &Behavior) -> &'static str {
    match b {
        Behavior::Growth { .. } => "Behavior::Growth",
        Behavior::Divide => "Behavior::Divide",
        Behavior::RandomWalk { .. } => "Behavior::RandomWalk",
        Behavior::Infection { .. } => "Behavior::Infection",
        Behavior::TumorGrowth { .. } => "Behavior::TumorGrowth",
        Behavior::Trade { .. } => "Behavior::Trade",
        Behavior::Reputation { .. } => "Behavior::Reputation",
    }
}

// ---------------------------------------------------------------------------
// Reader
// ---------------------------------------------------------------------------

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
    /// stream index -> global id, for back-reference resolution.
    objects: Vec<GlobalId>,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0, objects: Vec::new() }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], RootError> {
        if self.pos + n > self.buf.len() {
            return Err(RootError::Truncated);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn expect_tag(&mut self, expected: u8) -> Result<(), RootError> {
        let got = self.take(1)?[0];
        if got != expected {
            return Err(RootError::TypeMismatch { expected, got });
        }
        Ok(())
    }

    fn u8(&mut self) -> Result<u8, RootError> {
        self.expect_tag(tag::U8)?;
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16, RootError> {
        self.expect_tag(tag::U16)?;
        Ok(u16::from_be_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32, RootError> {
        self.expect_tag(tag::U32)?;
        Ok(u32::from_be_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, RootError> {
        self.expect_tag(tag::U64)?;
        Ok(u64::from_be_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64, RootError> {
        self.expect_tag(tag::F64)?;
        Ok(f64::from_bits(u64::from_be_bytes(self.take(8)?.try_into().unwrap())))
    }
    fn raw_u32(&mut self) -> Result<u32, RootError> {
        Ok(u32::from_be_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn str(&mut self) -> Result<String, RootError> {
        let n = self.raw_u32()? as usize;
        let s = self.take(n)?;
        Ok(String::from_utf8_lossy(s).into_owned())
    }

    /// Consume an object record header: byte count + class version
    /// (TBuffer::ReadVersion), validating both — the read-side half of the
    /// reflection machinery.
    fn begin_object(&mut self) -> Result<(), RootError> {
        let count = self.raw_u32()? as usize;
        if self.pos + count > self.buf.len() {
            return Err(RootError::Truncated);
        }
        let version = u16::from_be_bytes(self.take(2)?.try_into().unwrap());
        if version > SCHEMA_VERSION {
            return Err(RootError::UnknownClass(format!("version {version}")));
        }
        Ok(())
    }

    /// Parse and validate a class descriptor (schema-evolution hook: the
    /// reader walks the declared fields and checks version compatibility).
    fn class_descriptor(&mut self) -> Result<(), RootError> {
        let name = self.str()?;
        let version = self.raw_u32()?;
        if version > SCHEMA_VERSION as u32 {
            return Err(RootError::UnknownClass(name));
        }
        let nfields = self.raw_u32()?;
        for _ in 0..nfields {
            let _fname = self.str()?;
            let _ftag = self.take(1)?[0];
        }
        Ok(())
    }
}

/// Deserialize a message produced by [`serialize`]. Every agent and every
/// behavior vector is a fresh allocation (cost 4); the result lands in a
/// batch pairing each header with its behavior tail.
pub fn deserialize(buf: &[u8]) -> Result<AgentBatch, RootError> {
    let mut r = Reader::new(buf);
    if r.raw_u32()? != MESSAGE_MAGIC {
        return Err(RootError::BadMagic);
    }
    let descriptors = r.raw_u32()?;
    for _ in 0..descriptors {
        r.class_descriptor()?;
    }
    let n = r.raw_u32()? as usize;
    let mut batch = AgentBatch::with_capacity(n);
    for _ in 0..n {
        let (agent, behaviors) = read_agent(&mut r)?;
        batch.push(agent, &behaviors);
    }
    Ok(batch)
}

fn read_agent(r: &mut Reader) -> Result<(Agent, Vec<Behavior>), RootError> {
    r.expect_tag(tag::OBJ)?;
    r.begin_object()?;
    let class_id = r.u16()?;
    let gid = GlobalId::new(r.u32()?, r.u64()?);
    r.objects.push(gid);
    let position = Vec3::new(r.f64()?, r.f64()?, r.f64()?);
    let diameter = r.f64()?;
    let kind = match class_id {
        1 => AgentKind::Cell { cell_type: CellType::from_code(r.u8()?), adhesion: r.f64()? },
        2 => AgentKind::GrowingCell {
            volume: r.f64()?,
            growth_rate: r.f64()?,
            division_volume: r.f64()?,
        },
        3 => AgentKind::Person { state: SirState::from_code(r.u8()?), infected_for: r.u32()? },
        4 => AgentKind::TumorCell { cycle: r.f64()?, quiescent: r.u8()? != 0 },
        5 => AgentKind::Citizen { wealth: r.f64()?, reputation: r.f64()? },
        other => return Err(RootError::UnknownClass(format!("agent#{other}"))),
    };
    r.expect_tag(tag::VEC)?;
    let nb = r.raw_u32()? as usize;
    let mut behaviors = Vec::with_capacity(nb);
    for _ in 0..nb {
        r.begin_object()?;
        let bid = r.u16()?;
        behaviors.push(match bid {
            1 => Behavior::Growth { rate: r.f64()?, max_diameter: r.f64()? },
            2 => Behavior::Divide,
            3 => Behavior::RandomWalk { speed: r.f64()? },
            4 => Behavior::Infection {
                radius: r.f64()?,
                prob: r.f64()?,
                recovery_iters: r.u32()?,
            },
            5 => Behavior::TumorGrowth { cycle_rate: r.f64()?, max_diameter: r.f64()? },
            6 => Behavior::Trade { radius: r.f64()?, gain: r.f64()?, cooldown: r.u32()? },
            7 => Behavior::Reputation { score: r.f64()?, decay: r.f64()? },
            other => return Err(RootError::UnknownClass(format!("behavior#{other}"))),
        });
    }
    let marker = r.take(1)?[0];
    let neighbor_ref = match marker {
        tag::NULL => AgentPointer::NULL,
        tag::BACKREF => {
            let idx = r.raw_u32()?;
            let gid = *r
                .objects
                .get(idx as usize)
                .ok_or(RootError::BadBackref(idx))?;
            AgentPointer::to(gid)
        }
        tag::OBJ => AgentPointer::to(GlobalId::new(r.u32()?, r.u64()?)),
        got => return Err(RootError::TypeMismatch { expected: tag::OBJ, got }),
    };
    Ok((
        Agent { local_id: LocalId::INVALID, global_id: gid, position, diameter, kind, neighbor_ref },
        behaviors,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::agent::{growing_cell_behaviors, person_behaviors, Agent};

    fn sample() -> Vec<(Agent, Vec<Behavior>)> {
        let mut a = Agent::cell(Vec3::new(1.0, 2.0, 3.0), 10.0, CellType::A);
        a.global_id = GlobalId::new(0, 1);
        let mut b = Agent::person(Vec3::new(4.0, 5.0, 6.0), SirState::Recovered);
        b.global_id = GlobalId::new(0, 2);
        b.neighbor_ref = AgentPointer::to(a.global_id); // backref
        let mut c = Agent::growing_cell(Vec3::new(7.0, 8.0, 9.0), 12.0);
        c.global_id = GlobalId::new(1, 3);
        c.neighbor_ref = AgentPointer::to(GlobalId::new(9, 99)); // forward ref
        let mut d = Agent::citizen(Vec3::new(10.0, 11.0, 12.0), 250.0);
        d.global_id = GlobalId::new(1, 4);
        vec![
            (a, vec![]),
            (b, person_behaviors().to_vec()),
            (c, growing_cell_behaviors(12.0).to_vec()),
            (
                d,
                vec![
                    Behavior::Trade { radius: 2.0, gain: 0.5, cooldown: 3 },
                    Behavior::Reputation { score: 0.25, decay: 0.01 },
                ],
            ),
        ]
    }

    fn ser(pairs: &[(Agent, Vec<Behavior>)]) -> Vec<u8> {
        serialize(pairs.iter().map(|(a, bs)| (a, &bs[..])))
    }

    #[test]
    fn round_trip() {
        let agents = sample();
        let buf = ser(&agents);
        let restored = deserialize(&buf).unwrap();
        assert_eq!(agents.len(), restored.len());
        for (i, (o, obs)) in agents.iter().enumerate() {
            let r = &restored.agents[i];
            assert_eq!(o.global_id, r.global_id);
            assert_eq!(o.position, r.position);
            assert_eq!(o.kind, r.kind);
            assert_eq!(&obs[..], restored.behaviors(i));
            assert_eq!(o.neighbor_ref, r.neighbor_ref);
        }
    }

    #[test]
    fn backref_resolves_to_same_identity() {
        let agents = sample();
        let buf = ser(&agents);
        let restored = deserialize(&buf).unwrap();
        // b's pointer target equals a's id after dedup resolution.
        assert_eq!(restored.agents[1].neighbor_ref.target, restored.agents[0].global_id);
    }

    #[test]
    fn message_is_self_describing() {
        // Schema strings are physically in the message (cost 2).
        let buf = ser(&sample());
        let hay = String::from_utf8_lossy(&buf);
        assert!(hay.contains("Agent"));
        assert!(hay.contains("Behavior::Infection"));
        assert!(hay.contains("Behavior::Trade"));
        assert!(hay.contains("recovery_iters"));
    }

    #[test]
    fn values_are_big_endian_on_wire() {
        let mut a = Agent::cell(Vec3::ZERO, 0.0, CellType::A);
        a.global_id = GlobalId::new(0x0102_0304, 0);
        let buf = serialize([(&a, &[][..])].into_iter());
        // The rank 0x01020304 must appear big-endian somewhere after the
        // schema; search for the byte pattern.
        assert!(
            buf.windows(4).any(|w| w == [0x01, 0x02, 0x03, 0x04]),
            "expected big-endian rank bytes on the wire"
        );
    }

    #[test]
    fn type_mismatch_detected() {
        let agents = sample();
        let mut buf = ser(&agents);
        // Find the first F64 tag after the schema and corrupt it.
        let schema_end = {
            // agent count sits right before the first OBJ tag; find "OBJ".
            buf.iter().position(|&b| b == tag::OBJ).unwrap()
        };
        let f64_pos = buf[schema_end..].iter().position(|&b| b == tag::F64).unwrap() + schema_end;
        buf[f64_pos] = tag::U8;
        assert!(deserialize(&buf).is_err());
    }

    #[test]
    fn truncation_detected() {
        let buf = ser(&sample());
        assert_eq!(deserialize(&buf[..buf.len() - 3]).unwrap_err(), RootError::Truncated);
    }

    #[test]
    fn bad_magic_detected() {
        let mut buf = ser(&sample());
        buf[0] ^= 0xFF;
        assert_eq!(deserialize(&buf).unwrap_err(), RootError::BadMagic);
    }

    #[test]
    fn empty_message_round_trip() {
        let agents: Vec<(Agent, Vec<Behavior>)> = vec![];
        let buf = ser(&agents);
        assert!(deserialize(&buf).unwrap().is_empty());
    }

    #[test]
    fn wire_is_larger_than_ta_io() {
        // The generic format pays tags + schema; sanity-check the overhead
        // direction that Fig. 10d reports as roughly equivalent payload but
        // the runtime cost dominating elsewhere. (Schema is per-message,
        // tags per field.)
        let agents = sample();
        let root = ser(&agents).len();
        let ta = crate::io::ta_io::serialize_pairs(&agents).len();
        assert!(root > ta / 2, "root={root} ta={ta}"); // same order of magnitude
    }
}
