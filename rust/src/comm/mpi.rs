//! In-process simulated MPI with a zero-copy shared-memory wire.
//!
//! Semantics follow the subset of MPI the engine needs (§2.4.3):
//! non-blocking point-to-point (`isend` / `try_recv` ≈ `MPI_Isend` +
//! `MPI_Probe`/`MPI_Irecv`), blocking matched receive, barrier, and the
//! collectives (`allgather`, `allreduce`, `alltoallv`) used by
//! distributed initialization, load balancing and result reduction.
//!
//! Each rank owns a [`Communicator`] handle; mailboxes are per-rank
//! mutex-protected queues with condvar wakeups. Message payloads are
//! opaque bytes — all typing happens in the serialization layer, exactly
//! as with real MPI buffers. Every transfer is charged simulated network
//! seconds per the configured [`NetworkModel`].
//!
//! # Frames: the zero-copy transport
//!
//! Mailbox messages are refcounted pooled [`Frame`]s drawn from the
//! world's shared [`FramePool`] — the in-process model of an RDMA-style
//! transport whose send buffers live in a shared segment. A sender either
//! *publishes* a buffer it already owns ([`Communicator::isend_frame`] /
//! [`Communicator::isend`]; no copy — the mailbox holds the very bytes
//! the sender wrote) or *stages* borrowed slices into a pooled frame
//! ([`Communicator::isend_parts`]; one copy, the modeled DMA write, but
//! no allocation). The receiver gets the frame back by reference
//! ([`RecvMsg::data`]); when the last reference drops, the buffer
//! recycles into the pool for the next sender — so the steady state
//! circulates a fixed set of buffers and allocates nothing.
//!
//! ```
//! use teraagent::comm::mpi::{FramePool, Frame};
//! let pool = FramePool::new();
//! let mut buf = pool.take();           // pooled writable buffer
//! buf.extend_from_slice(b"wire");
//! let frame: Frame = buf.seal();       // refcounted, recycles on drop
//! assert_eq!(&frame[..], b"wire");
//! let stats = pool.stats();
//! assert_eq!((stats.outstanding, stats.free), (1, 0));
//! drop(frame);
//! let stats = pool.stats();
//! assert_eq!((stats.outstanding, stats.free), (0, 1)); // buffer recycled
//! ```
//!
//! See `ARCHITECTURE.md` §"Transport and frame lifecycle" for the full
//! journey of a frame through the aura exchange.
//!
//! # Fault tolerance
//!
//! The transport is the seam where faults are injected and survived:
//! a [`Communicator`] can carry a [`ChaosState`](super::chaos::ChaosState)
//! (deterministic, seed-driven frame faults applied at publish time), a
//! bounded [`Communicator::recv_any_deadline`] replaces the infinite
//! block with a typed [`CommError`], and *reliable mode*
//! ([`Communicator::set_reliable`]) keeps a refcounted archive of the
//! last published frames per `(dst, tag)` so receivers can request
//! retransmission ([`Communicator::request_retry`] /
//! [`Communicator::service_retry_queue`]). See `ARCHITECTURE.md`
//! §"Fault tolerance" for the recovery ladder.

// Wire path: panics on malformed remote input are forbidden; internal
// invariants use `expect` with a justification.
#![cfg_attr(not(test), deny(clippy::unwrap_used))]

use super::chaos::{ChaosState, ChaosStats, FaultPlan};
use super::network::NetworkModel;
use super::transport::{MailboxCore, Transport, TransportKind, TransportStats};
use crate::util::crc32::Crc32;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Message tag. The engine uses distinct tags per protocol step.
pub type Tag = u32;

/// Well-known tags.
pub mod tags {
    use super::Tag;
    pub const AURA: Tag = 1;
    pub const MIGRATION: Tag = 2;
    pub const BALANCE: Tag = 3;
    pub const CONTROL: Tag = 4;
    pub const CHUNK: Tag = 5;
    /// Retransmission requests (NACKs): payload `[orig_tag u32][msg_id u32]`
    /// LE. Control-plane traffic — never subject to chaos injection.
    pub const RETRY: Tag = 6;
    /// Delta-stream resync requests: payload `[orig_tag u32]` LE. The
    /// receiver asks the sender to fall back to a full (non-delta)
    /// refresh on that channel. Control-plane traffic like [`RETRY`].
    pub const RESYNC: Tag = 7;
    /// Zero-byte liveness heartbeats: emitted by a rank sitting in a
    /// long bounded wait (e.g. waiting out a dead peer's silence) so a
    /// stalled-but-alive rank is never mistaken for a dead one by peers
    /// stalled on *it* in turn. Control-plane traffic like [`RETRY`].
    pub const HEARTBEAT: Tag = 8;
    /// Death notices: payload is one LE `u32` per dead rank. A rank
    /// that declares a peer dead broadcasts the verdict so ranks that
    /// never wait on the dead peer directly still learn of the death
    /// and run the same reshard path. Control-plane traffic.
    pub const DEATH: Tag = 9;
    /// Per-round all-to-all tags live above this base.
    pub const ALLTOALL_BASE: Tag = 0x4000_0000;
    /// Per-round collective (p2p allgather fallback) tags live above this
    /// base: round `r` uses `COLLECTIVE_BASE + 2r` for the gather leg and
    /// `COLLECTIVE_BASE + 2r + 1` for the broadcast leg. Control-plane
    /// traffic — never subject to chaos injection and excluded from the
    /// send-stream audit (like [`RETRY`]).
    pub const COLLECTIVE_BASE: Tag = 0x8000_0000;

    /// Tag for the all-to-all exchange of `round`.
    pub fn alltoall_round(round: u32) -> Tag {
        ALLTOALL_BASE + round
    }

    /// Gather-leg tag of p2p collective round `round`.
    pub fn collective_gather(round: u64) -> Tag {
        COLLECTIVE_BASE + ((round as u32) << 1)
    }

    /// Broadcast-leg tag of p2p collective round `round`.
    pub fn collective_bcast(round: u64) -> Tag {
        COLLECTIVE_BASE + ((round as u32) << 1) + 1
    }

    /// Whether `tag` is control-plane traffic: exempt from chaos
    /// injection and excluded from the deterministic send-stream audit
    /// (retransmissions and heartbeats are timing-dependent; collective
    /// legs differ by backend).
    pub fn is_control(tag: Tag) -> bool {
        matches!(tag, RETRY | RESYNC | HEARTBEAT | DEATH) || tag >= COLLECTIVE_BASE
    }
}

/// Typed transport errors — what a bounded receive surfaces instead of
/// deadlocking (the "no malformed byte sequence or lost frame can hang a
/// rank" contract).
#[derive(Debug, Clone, PartialEq)]
pub enum CommError {
    /// No matching message arrived within the deadline.
    Timeout { tag: Tag, waited_secs: f64 },
    /// A batched receive exhausted its retry budget; `pending` lists the
    /// sources whose messages never completed.
    RetriesExhausted { tag: Tag, pending: Vec<u32> },
    /// The liveness plane declared one or more peers dead: their messages
    /// were still missing after the retry budget *and* they had been
    /// silent on every tag for longer than the configured death timeout.
    /// Unlike [`CommError::RetriesExhausted`] (which the engine answers
    /// with resync/restore against a still-live peer), this is the
    /// escalation that triggers the reshard rung of the recovery ladder.
    RankDead { tag: Tag, dead: Vec<u32> },
}

impl std::fmt::Display for CommError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CommError::Timeout { tag, waited_secs } => {
                write!(f, "receive timed out after {waited_secs:.3}s (tag {tag})")
            }
            CommError::RetriesExhausted { tag, pending } => {
                write!(f, "retries exhausted on tag {tag}; incomplete sources {pending:?}")
            }
            CommError::RankDead { tag, dead } => {
                write!(f, "rank(s) {dead:?} declared dead while receiving tag {tag}")
            }
        }
    }
}

impl std::error::Error for CommError {}

/// Counters of one [`FramePool`]'s lifecycle (see [`FramePool::stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FramePoolStats {
    /// Recycled buffers currently parked in the pool.
    pub free: usize,
    /// Sealed [`Frame`]s alive right now (not yet dropped or unwrapped).
    pub outstanding: usize,
    /// Maximum `outstanding` ever observed — the pool's high-water mark.
    /// Bounded by the peak number of in-flight messages, not by traffic
    /// volume: a leak shows up here as unbounded growth.
    pub high_water: usize,
    /// Buffers ever created because the free list was empty (warm-up).
    pub created: u64,
    /// Buffer returns to the free list (drops of pooled frames/leases).
    pub recycled: u64,
}

#[derive(Debug, Default)]
struct PoolShared {
    free: Mutex<Vec<Vec<u8>>>,
    outstanding: AtomicUsize,
    high_water: AtomicUsize,
    created: AtomicU64,
    recycled: AtomicU64,
}

/// A shared recycler of transport buffers — the in-process model of a
/// shared-memory segment / registered RDMA region. Cloning is cheap
/// (`Arc`); all ranks of an [`MpiWorld`] share one pool, so a buffer a
/// receiver releases is immediately reusable by any sender.
///
/// Buffers move through three states: **leased** (a writable
/// [`FrameBuf`] from [`take`](FramePool::take), or a raw `Vec<u8>` from
/// [`take_vec`](FramePool::take_vec)), **sealed** (an immutable
/// refcounted [`Frame`]), and **free** (parked in the pool). Every exit
/// path returns the buffer: dropping an unsealed `FrameBuf` recycles it,
/// and dropping the last `Frame` reference recycles it — a frame cannot
/// leak or be recycled twice by construction (the recycle runs in the
/// single `Drop` of its refcounted inner cell).
#[derive(Clone, Debug, Default)]
pub struct FramePool {
    inner: Arc<PoolShared>,
}

impl FramePool {
    pub fn new() -> FramePool {
        FramePool::default()
    }

    fn pop_vec(&self) -> Vec<u8> {
        // Lock poisoning means another rank thread panicked — propagating
        // the panic here is the correct response, not a wire error.
        let popped = self.inner.free.lock().expect("poisoned frame-pool lock").pop();
        match popped {
            Some(v) => v,
            None => {
                self.inner.created.fetch_add(1, Ordering::Relaxed);
                Vec::new()
            }
        }
    }

    fn put_back(&self, mut buf: Vec<u8>) {
        buf.clear();
        self.inner.recycled.fetch_add(1, Ordering::Relaxed);
        self.inner.free.lock().expect("poisoned frame-pool lock").push(buf);
    }

    /// Lease a writable buffer (empty; capacity recycled). Seal it into a
    /// [`Frame`] to publish, or drop it to return it to the pool.
    pub fn take(&self) -> FrameBuf {
        FrameBuf { buf: self.pop_vec(), pool: Some(self.clone()) }
    }

    /// Lease a raw `Vec<u8>` (empty; capacity recycled) — for callers
    /// that thread the buffer through an encoder before sealing it with
    /// [`FramePool::seal`]. The lease is untracked: return it via
    /// [`FramePool::recycle_vec`] or `seal` (dropping it instead merely
    /// forfeits the capacity).
    pub fn take_vec(&self) -> Vec<u8> {
        self.pop_vec()
    }

    /// Return a leased raw buffer to the free list.
    pub fn recycle_vec(&self, buf: Vec<u8>) {
        self.put_back(buf);
    }

    /// Seal an owned buffer into a pooled [`Frame`]: the frame holds the
    /// very bytes of `buf` (no copy), and the buffer recycles here when
    /// the last reference drops.
    pub fn seal(&self, buf: Vec<u8>) -> Frame {
        let n = self.inner.outstanding.fetch_add(1, Ordering::Relaxed) + 1;
        self.inner.high_water.fetch_max(n, Ordering::Relaxed);
        Frame { inner: Arc::new(FrameInner { buf: Some(buf), pool: Some(self.clone()) }) }
    }

    /// Lifecycle counters (tests assert leak-freedom and bounded
    /// high-water marks against these).
    pub fn stats(&self) -> FramePoolStats {
        FramePoolStats {
            free: self.inner.free.lock().expect("poisoned frame-pool lock").len(),
            outstanding: self.inner.outstanding.load(Ordering::Relaxed),
            high_water: self.inner.high_water.load(Ordering::Relaxed),
            created: self.inner.created.load(Ordering::Relaxed),
            recycled: self.inner.recycled.load(Ordering::Relaxed),
        }
    }

    /// Bytes parked in the free list (memory accounting).
    pub fn approx_bytes(&self) -> u64 {
        self.inner.free.lock().expect("poisoned frame-pool lock").iter().map(|b| b.capacity() as u64).sum()
    }

    /// Trim the free list down to the demand observed since the last
    /// trim, and re-arm the high-water mark for the next epoch. The pool
    /// keeps `high_water - outstanding` free buffers (the peak concurrent
    /// demand of the epoch that just ended, minus buffers still out) and
    /// releases the rest; the watermark then restarts from the current
    /// `outstanding` so a later epoch with a smaller neighbor set
    /// measures its own, smaller peak. Returns the number of buffers
    /// released. The sizing policy hook for rebalance/reshard: a rank
    /// whose neighbor set shrank calls this so buffers sized for dead or
    /// departed peers don't stay parked forever.
    pub fn shrink_to_watermark(&self) -> usize {
        let outstanding = self.inner.outstanding.load(Ordering::Relaxed);
        let peak = self.inner.high_water.swap(outstanding, Ordering::Relaxed);
        let keep = peak.saturating_sub(outstanding);
        let mut free = self.inner.free.lock().expect("poisoned frame-pool lock");
        let before = free.len();
        if before > keep {
            free.truncate(keep);
        }
        before - free.len()
    }
}

/// A writable pooled buffer, leased from a [`FramePool`]. Write the wire
/// bytes, then [`seal`](FrameBuf::seal) it into an immutable [`Frame`];
/// dropping it unsealed returns the buffer to the pool.
#[derive(Debug)]
pub struct FrameBuf {
    buf: Vec<u8>,
    /// `None` once sealed (disarms the recycle-on-drop).
    pool: Option<FramePool>,
}

impl FrameBuf {
    /// Append bytes to the frame under construction.
    pub fn extend_from_slice(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// The underlying vector, for writers that need full `Vec` access.
    pub fn as_mut_vec(&mut self) -> &mut Vec<u8> {
        &mut self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Freeze into an immutable refcounted [`Frame`] (no copy).
    pub fn seal(mut self) -> Frame {
        let buf = std::mem::take(&mut self.buf);
        let pool = self.pool.take().expect("frame sealed twice");
        pool.seal(buf)
    }
}

impl Drop for FrameBuf {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.take() {
            pool.put_back(std::mem::take(&mut self.buf));
        }
    }
}

#[derive(Debug)]
struct FrameInner {
    buf: Option<Vec<u8>>,
    /// `None` for frames wrapping a caller-owned vector ([`Frame::owned`]).
    pool: Option<FramePool>,
}

impl Drop for FrameInner {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.take() {
            pool.inner.outstanding.fetch_sub(1, Ordering::Relaxed);
            if let Some(buf) = self.buf.take() {
                pool.put_back(buf);
            }
        }
    }
}

/// An immutable, refcounted transport buffer — what the mailbox holds and
/// what a receive hands back. Cloning shares the same bytes (an `Arc`
/// bump, no copy); when the last clone drops, a pooled frame's buffer
/// returns to its [`FramePool`].
#[derive(Clone, Debug)]
pub struct Frame {
    inner: Arc<FrameInner>,
}

impl Frame {
    /// Wrap a caller-owned vector without pooling (no copy; the vector is
    /// simply freed when the last reference drops). Collectives and
    /// one-shot sends use this.
    pub fn owned(buf: Vec<u8>) -> Frame {
        Frame { inner: Arc::new(FrameInner { buf: Some(buf), pool: None }) }
    }

    #[inline]
    pub fn as_slice(&self) -> &[u8] {
        self.inner.buf.as_deref().expect("frame buffer already taken")
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.as_slice().len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.as_slice().is_empty()
    }

    /// Move the bytes out as a plain `Vec<u8>`. Zero-copy when this is
    /// the only reference (the buffer is *stolen* — a pooled frame's
    /// buffer then does not return to its pool); copies otherwise.
    pub fn into_vec(self) -> Vec<u8> {
        match Arc::try_unwrap(self.inner) {
            Ok(mut inner) => inner.buf.take().expect("frame buffer already taken"),
            Err(shared) => shared.buf.as_deref().expect("frame buffer already taken").to_vec(),
        }
    }

    pub fn to_vec(&self) -> Vec<u8> {
        self.as_slice().to_vec()
    }
}

impl std::ops::Deref for Frame {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl PartialEq<[u8]> for Frame {
    fn eq(&self, other: &[u8]) -> bool {
        self.as_slice() == other
    }
}

/// A received message. `data` is a borrowed view of the very frame the
/// sender published — dropping it recycles the buffer.
#[derive(Debug, Clone)]
pub struct RecvMsg {
    pub src: u32,
    pub tag: Tag,
    pub data: Frame,
}

/// One collective rendezvous slot.
#[derive(Debug, Default)]
struct CollectiveSlot {
    round: u64,
    deposits: Vec<Option<Vec<u8>>>,
    /// Count of ranks that picked up the result of the current round.
    collected: usize,
    results: Option<Vec<Vec<u8>>>,
}

/// Shared world state of the in-process (thread-per-rank) backend.
pub struct MpiWorld {
    size: usize,
    mailboxes: Vec<Arc<MailboxCore>>,
    barrier: std::sync::Barrier,
    collective: Mutex<CollectiveSlot>,
    collective_cv: Condvar,
    network: NetworkModel,
    /// Shared transport-buffer recycler (the modeled shared segment).
    frames: FramePool,
    /// Total wire bytes moved (all ranks).
    pub total_wire_bytes: AtomicU64,
    /// Total messages.
    pub total_messages: AtomicU64,
}

impl MpiWorld {
    /// Create a world with `size` ranks over the given network model.
    pub fn new(size: usize, network: NetworkModel) -> Arc<MpiWorld> {
        assert!(size >= 1);
        Arc::new(MpiWorld {
            size,
            mailboxes: (0..size).map(|_| Arc::new(MailboxCore::new(size))).collect(),
            barrier: std::sync::Barrier::new(size),
            collective: Mutex::new(CollectiveSlot {
                round: 0,
                deposits: vec![None; size],
                collected: 0,
                results: None,
            }),
            collective_cv: Condvar::new(),
            network,
            frames: FramePool::new(),
            total_wire_bytes: AtomicU64::new(0),
            total_messages: AtomicU64::new(0),
        })
    }

    /// The world's shared [`FramePool`].
    pub fn frame_pool(&self) -> &FramePool {
        &self.frames
    }

    /// Handle for `rank`.
    pub fn communicator(self: &Arc<Self>, rank: u32) -> Communicator {
        assert!((rank as usize) < self.size);
        Communicator::new(Box::new(InProcTransport::new(Arc::clone(self), rank, true)), self.network)
    }

    /// Handle for `rank` with the backend-native collectives disabled, so
    /// the communicator exercises its p2p gather+broadcast fallback — the
    /// path multiprocess backends run — while staying in one process.
    /// Test-oriented but behavior-identical in results.
    pub fn communicator_p2p_collectives(self: &Arc<Self>, rank: u32) -> Communicator {
        assert!((rank as usize) < self.size);
        Communicator::new(Box::new(InProcTransport::new(Arc::clone(self), rank, false)), self.network)
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// The condvar rendezvous behind the in-process native allgather:
    /// deposit `data` in `rank`'s slot, wait for all ranks, pick up the
    /// full round. Ranks must call collectives in the same order.
    fn allgather_slot(&self, rank: u32, data: Vec<u8>) -> Vec<Vec<u8>> {
        let size = self.size;
        let mut slot = self.collective.lock().expect("poisoned collective lock");
        let my_round = slot.round;
        slot.deposits[rank as usize] = Some(data);
        if slot.deposits.iter().all(|d| d.is_some()) {
            // Last depositor publishes results and advances the round.
            let results: Vec<Vec<u8>> = slot
                .deposits
                .iter_mut()
                .map(|d| d.take().expect("all deposits present (just checked)"))
                .collect();
            slot.results = Some(results);
            slot.collected = 0;
            self.collective_cv.notify_all();
        } else {
            while slot.results.is_none() || slot.round != my_round {
                slot = self.collective_cv.wait(slot).expect("poisoned collective lock");
                if slot.round != my_round {
                    break;
                }
            }
        }
        let out = slot.results.as_ref().expect("collective results missing").clone();
        slot.collected += 1;
        if slot.collected == size {
            slot.results = None;
            slot.round += 1;
            self.collective_cv.notify_all();
        } else {
            // Wait for round completion to prevent a fast rank from
            // entering the next collective early and clobbering deposits.
            while slot.round == my_round && slot.results.is_some() {
                slot = self.collective_cv.wait(slot).expect("poisoned collective lock");
            }
        }
        out
    }
}

/// The thread-per-rank backend of PRs 1–7: a send is a push into the
/// destination's shared-memory mailbox (a pointer move — the zero-copy
/// wire), collectives are condvar rendezvous, and there is never pending
/// nonblocking work to pump.
pub struct InProcTransport {
    world: Arc<MpiWorld>,
    rank: u32,
    mailbox: Arc<MailboxCore>,
    /// When false, `native_allgather`/`native_barrier` report unavailable
    /// so the communicator runs its p2p fallback (the multiprocess path).
    native_collectives: bool,
    stats: TransportStats,
}

impl InProcTransport {
    pub fn new(world: Arc<MpiWorld>, rank: u32, native_collectives: bool) -> InProcTransport {
        let mailbox = Arc::clone(&world.mailboxes[rank as usize]);
        InProcTransport { world, rank, mailbox, native_collectives, stats: TransportStats::default() }
    }
}

impl Transport for InProcTransport {
    fn kind(&self) -> TransportKind {
        TransportKind::InProcess
    }

    fn rank(&self) -> u32 {
        self.rank
    }

    fn size(&self) -> usize {
        self.world.size
    }

    fn frame_pool(&self) -> &FramePool {
        &self.world.frames
    }

    fn mailbox(&self) -> &Arc<MailboxCore> {
        &self.mailbox
    }

    fn send(&mut self, dst: u32, tag: Tag, frame: Frame) {
        if dst != self.rank {
            // Loopback stays off the wire counters on every backend.
            self.stats.frames_sent += 1;
            self.stats.bytes_sent += frame.len() as u64;
            self.world.total_wire_bytes.fetch_add(frame.len() as u64, Ordering::Relaxed);
            self.world.total_messages.fetch_add(1, Ordering::Relaxed);
        }
        self.world.mailboxes[dst as usize].push(self.rank, tag, frame);
    }

    fn pump(&mut self) -> usize {
        0 // Sends complete synchronously; nothing is ever pending.
    }

    fn inflight(&self) -> usize {
        0
    }

    fn stats(&self) -> TransportStats {
        self.stats
    }

    fn native_allgather(&mut self, data: &[u8]) -> Option<Vec<Vec<u8>>> {
        if !self.native_collectives {
            return None;
        }
        Some(self.world.allgather_slot(self.rank, data.to_vec()))
    }

    fn native_barrier(&mut self) -> bool {
        if !self.native_collectives {
            return false;
        }
        self.world.barrier.wait();
        true
    }

    fn shutdown(&mut self) {}
}

/// Per-peer liveness bookkeeping (opt-in; see
/// [`Communicator::enable_liveness`]). Instead of a dedicated heartbeat
/// protocol, liveness piggybacks on the traffic the engine already
/// exchanges every iteration (aura frames, alltoallv rounds, control
/// acks, retry/resync requests): *any* received message proves its
/// sender alive, and a peer is overdue only once it has been silent on
/// every tag for longer than the death timeout.
#[derive(Debug)]
struct Liveness {
    /// Silence longer than this, while a receive still wants the peer's
    /// messages, escalates to [`CommError::RankDead`].
    timeout: Duration,
    /// Per-rank instant of the last message received from that rank.
    last_heard: Vec<Instant>,
    /// Ranks this communicator has declared dead. Sticky: a dead rank
    /// never rejoins (late frames from it are dropped, sends to it are
    /// skipped).
    dead: Vec<bool>,
}

/// Per-rank communicator handle. Owns the backend as `Box<dyn Transport>`
/// — everything protocol-level (chaos, retries, liveness, collectives,
/// matching/blocking receive semantics) lives here, backend-independent.
pub struct Communicator {
    transport: Box<dyn Transport>,
    /// Clone of the transport's inbound mailbox (all receives match here).
    mailbox: Arc<MailboxCore>,
    rank: u32,
    size: usize,
    network: NetworkModel,
    /// Simulated network seconds charged to this rank.
    pub network_secs: f64,
    /// Wall seconds this rank spent computing/verifying frame checksums
    /// (send side; the receive side is metered by the reassembler).
    pub checksum_secs: f64,
    /// Data-plane wire bytes this rank published (loopback excluded).
    pub wire_bytes_sent: u64,
    /// Data-plane messages this rank published (loopback excluded).
    pub wire_messages_sent: u64,
    /// Per-`(dst, tag)` monotone frame sequence counters (stamped into
    /// the frame header by the batching layer).
    seqs: HashMap<(u32, Tag), u32>,
    /// Deterministic fault injector, applied at frame-publish time.
    chaos: Option<Box<ChaosState>>,
    /// Reliable mode: archive published frames for retransmission.
    reliable: bool,
    /// Last archived message per `(dst, tag)`: `(msg_id, frames)`.
    /// Frames are refcounted — archiving costs one `Arc` bump per frame.
    archive: HashMap<(u32, Tag), (u32, Vec<Frame>)>,
    /// Frames re-published in response to retry requests.
    retransmits_served: u64,
    /// alltoallv envelopes rejected on receive (CRC/round/shape damage).
    a2a_rejects: u64,
    /// Retry requests (NACKs) sent from the alltoallv receive loop.
    a2a_nacks: u64,
    /// Opt-in peer-liveness tracking (None = feature off, zero cost).
    liveness: Option<Liveness>,
    /// Monotone p2p-collective round counter (tags the fallback legs).
    collective_round: u64,
    /// Opt-in running CRC over the clean data-plane send stream (dst,
    /// tag, len, payload per frame; control tags and retransmissions
    /// excluded) — the cross-backend byte-identity witness.
    audit: Option<Crc32>,
    /// Suppresses audit updates while re-publishing archived frames
    /// (retransmissions are timing-dependent, not part of the clean
    /// stream).
    audit_paused: bool,
}

impl Drop for Communicator {
    fn drop(&mut self) {
        self.transport.shutdown();
    }
}

impl Communicator {
    /// Wrap a backend. Backends construct their own mailbox/pool; this
    /// layers the protocol state machine on top.
    pub fn new(transport: Box<dyn Transport>, network: NetworkModel) -> Communicator {
        let mailbox = Arc::clone(transport.mailbox());
        let rank = transport.rank();
        let size = transport.size();
        Communicator {
            transport,
            mailbox,
            rank,
            size,
            network,
            network_secs: 0.0,
            checksum_secs: 0.0,
            wire_bytes_sent: 0,
            wire_messages_sent: 0,
            seqs: HashMap::new(),
            chaos: None,
            reliable: false,
            archive: HashMap::new(),
            retransmits_served: 0,
            a2a_rejects: 0,
            a2a_nacks: 0,
            liveness: None,
            collective_round: 0,
            audit: None,
            audit_paused: false,
        }
    }

    #[inline]
    pub fn rank(&self) -> u32 {
        self.rank
    }

    #[inline]
    pub fn size(&self) -> usize {
        self.size
    }

    /// Which backend this communicator runs over.
    #[inline]
    pub fn transport_kind(&self) -> TransportKind {
        self.transport.kind()
    }

    /// The backend's lifetime counters (stalls, drops, fallbacks).
    pub fn transport_stats(&self) -> TransportStats {
        self.transport.stats()
    }

    /// Drive pending nonblocking transport work (flush queued writes,
    /// harvest completions). The engine calls this once per iteration so
    /// a backend with a send backlog makes progress even when the rank
    /// computes for a long stretch between receives. No-op in-process.
    pub fn pump(&mut self) -> usize {
        self.transport.pump()
    }

    /// Sends accepted by the transport but not yet on the wire.
    pub fn send_inflight(&self) -> usize {
        self.transport.inflight()
    }

    /// Start auditing the clean data-plane send stream: a running CRC
    /// over `(dst, tag, len, payload)` of every published frame, skipping
    /// control tags and retransmissions. Two ranks that run the same
    /// seeded simulation over different backends must finish with equal
    /// audit digests — the determinism suite's wire-level witness.
    pub fn enable_stream_audit(&mut self) {
        self.audit = Some(Crc32::new());
    }

    /// Current audit digest (None when auditing is off).
    pub fn stream_audit_crc(&self) -> Option<u32> {
        self.audit.map(|a| a.finalize())
    }

    /// The pool senders lease publishable buffers from — world-shared
    /// in-process (receiver drops recycle to the sender), per-process on
    /// multiprocess backends.
    pub fn frame_pool(&self) -> &FramePool {
        self.transport.frame_pool()
    }

    /// Publish a sealed frame to `dst` — the zero-copy send: the mailbox
    /// holds the very buffer the sender wrote, and the receiver reads it
    /// in place. The network model charges the simulated wire time to the
    /// sender as for any send.
    ///
    /// When a [`ChaosState`] is installed, data-plane frames route through
    /// it first: the fault plan may drop, hold (delay/reorder), duplicate,
    /// truncate, or bit-flip the frame before anything reaches the
    /// mailbox. Control-plane tags ([`tags::RETRY`], [`tags::RESYNC`],
    /// [`tags::HEARTBEAT`], [`tags::DEATH`]) bypass injection so
    /// recovery itself cannot livelock.
    pub fn isend_frame(&mut self, dst: u32, tag: Tag, frame: Frame) {
        assert!((dst as usize) < self.size, "invalid destination rank {dst}");
        // Audit the *intended* clean stream — before chaos mutates it and
        // skipping retransmissions — so every backend running the same
        // protocol computes the same digest.
        if !tags::is_control(tag) && !self.audit_paused {
            if let Some(a) = self.audit.as_mut() {
                *a = a
                    .update(&dst.to_le_bytes())
                    .update(&tag.to_le_bytes())
                    .update(&(frame.len() as u32).to_le_bytes())
                    .update(frame.as_slice());
            }
        }
        if self.chaos.is_some() && !tags::is_control(tag) {
            let mut chaos = self.chaos.take().expect("chaos presence just checked");
            let out = chaos.apply(self.rank, dst, tag, frame);
            self.chaos = Some(chaos);
            for f in out {
                self.publish(dst, tag, f);
            }
        } else {
            self.publish(dst, tag, frame);
        }
    }

    /// Raw transport handoff + accounting (below the chaos seam).
    fn publish(&mut self, dst: u32, tag: Tag, frame: Frame) {
        if dst != self.rank {
            let bytes = frame.len();
            self.network_secs += self.network.transfer_secs(bytes);
            self.wire_bytes_sent += bytes as u64;
            self.wire_messages_sent += 1;
        }
        self.transport.send(dst, tag, frame);
    }

    /// Install a deterministic fault injector on this rank's sends.
    /// Implies reliable mode (frames are archived for retransmission).
    pub fn install_chaos(&mut self, plan: FaultPlan) {
        self.chaos = Some(Box::new(ChaosState::new(plan)));
        self.reliable = true;
    }

    /// Counters of faults injected so far (zero when no chaos installed).
    pub fn chaos_stats(&self) -> ChaosStats {
        self.chaos.as_ref().map(|c| c.stats()).unwrap_or_default()
    }

    /// The installed fault plan, if any. The engine consults this to
    /// learn whether *this* rank is scripted to die
    /// ([`FaultPlan::kill_at_iteration`]) so the victim can exit its
    /// iteration loop cleanly instead of spinning against a transport
    /// that swallows everything it sends.
    pub fn chaos_plan(&self) -> Option<&FaultPlan> {
        self.chaos.as_ref().map(|c| c.plan())
    }

    /// Whether this rank's chaos state has latched the kill fault (all
    /// its sends are being swallowed). False when no chaos is installed.
    pub fn chaos_dead(&self) -> bool {
        self.chaos.as_ref().is_some_and(|c| c.is_dead())
    }

    /// Turn on peer-liveness tracking: every received message marks its
    /// sender alive, and [`Communicator::overdue`] reports peers silent
    /// longer than `timeout`. All peers start as heard-from-now, so a
    /// freshly enabled plane never declares anyone dead before a full
    /// timeout of genuine silence has elapsed.
    pub fn enable_liveness(&mut self, timeout: Duration) {
        let now = Instant::now();
        self.liveness = Some(Liveness {
            timeout,
            last_heard: vec![now; self.size],
            dead: vec![false; self.size],
        });
    }

    /// Whether liveness tracking is on.
    #[inline]
    pub fn liveness_enabled(&self) -> bool {
        self.liveness.is_some()
    }

    /// Record a received message from `src` (called by every receive
    /// path). Associated fn so receive loops can update liveness while
    /// holding the mailbox guard (disjoint field borrows).
    #[inline]
    fn note_heard(liveness: &mut Option<Liveness>, src: u32) {
        if let Some(l) = liveness.as_mut() {
            l.last_heard[src as usize] = Instant::now();
        }
    }

    /// Declare `rank` dead: sends to it are skipped, collectives stop
    /// waiting for it, and [`Communicator::dead_ranks`] reports it.
    /// Sticky — there is no resurrection; a replacement peer would join
    /// as a new world.
    pub fn mark_dead(&mut self, rank: u32) {
        if let Some(l) = self.liveness.as_mut() {
            l.dead[rank as usize] = true;
        }
    }

    /// Whether `rank` has been declared dead by this communicator.
    pub fn is_dead(&self, rank: u32) -> bool {
        self.liveness.as_ref().is_some_and(|l| l.dead[rank as usize])
    }

    /// Ranks declared dead so far, ascending.
    pub fn dead_ranks(&self) -> Vec<u32> {
        match self.liveness.as_ref() {
            Some(l) => {
                l.dead.iter().enumerate().filter(|(_, d)| **d).map(|(i, _)| i as u32).collect()
            }
            None => Vec::new(),
        }
    }

    /// Among `pending` peers, those that are already marked dead or have
    /// been silent (no message on any tag) longer than the liveness
    /// timeout. Empty when liveness is off — callers fall back to the
    /// plain retries-exhausted path, preserving pre-liveness behavior.
    ///
    /// The silence clock is receive-based, but a receive loop filtered
    /// to one tag would never consume a queued heartbeat — so before
    /// declaring a silent peer overdue, the mailbox is probed: anything
    /// queued from that peer (on any tag) proves it alive even though
    /// nothing has been consumed from it yet.
    pub fn overdue(&self, pending: &[u32]) -> Vec<u32> {
        let Some(l) = self.liveness.as_ref() else {
            return Vec::new();
        };
        let now = Instant::now();
        pending
            .iter()
            .copied()
            .filter(|&s| {
                if l.dead[s as usize] {
                    return true;
                }
                now.duration_since(l.last_heard[s as usize]) >= l.timeout
                    && !self.mailbox.has_from(s)
            })
            .collect()
    }

    /// Enable/disable reliable mode without fault injection. In reliable
    /// mode batched sends archive their frames (refcount clones, no
    /// copies) so [`Communicator::service_retry_queue`] can re-publish
    /// them; the clean path keeps archiving off so the frame pool's
    /// steady-state invariants (one circulating buffer) are untouched.
    pub fn set_reliable(&mut self, on: bool) {
        self.reliable = on;
        if !on {
            self.archive.clear();
        }
    }

    #[inline]
    pub fn reliable(&self) -> bool {
        self.reliable
    }

    /// Next monotone sequence number for the `(dst, tag)` channel.
    #[inline]
    pub fn next_seq(&mut self, dst: u32, tag: Tag) -> u32 {
        let c = self.seqs.entry((dst, tag)).or_insert(0);
        let s = *c;
        *c = c.wrapping_add(1);
        s
    }

    /// Archive the frames of the message just sent on `(dst, tag)` for
    /// retransmission (reliable mode only; refcount clones, no copy).
    /// Only the latest message per channel is kept — the exchange
    /// protocol has at most one in-flight batched message per channel.
    pub fn archive_frames(&mut self, dst: u32, tag: Tag, msg_id: u32, frames: Vec<Frame>) {
        if self.reliable && !frames.is_empty() {
            self.archive.insert((dst, tag), (msg_id, frames));
        }
    }

    /// Ask `src` to retransmit message `msg_id` of `tag` (a NACK). The
    /// request travels on [`tags::RETRY`], exempt from chaos.
    pub fn request_retry(&mut self, src: u32, tag: Tag, msg_id: u32) {
        let mut p = Vec::with_capacity(8);
        p.extend_from_slice(&tag.to_le_bytes());
        p.extend_from_slice(&msg_id.to_le_bytes());
        self.isend(src, tags::RETRY, p);
    }

    /// Serve queued retransmission requests from the archive. Returns the
    /// number of frames re-published (also accumulated in
    /// [`Communicator::retransmits_served`]). Malformed or unmatched
    /// requests are ignored — the control plane is best-effort; the
    /// requester's bounded retry loop is what guarantees progress.
    pub fn service_retry_queue(&mut self) -> u64 {
        let mut served = 0u64;
        while let Some(m) = self.try_recv(None, Some(tags::RETRY)) {
            let b = m.data.as_slice();
            if b.len() != 8 {
                continue;
            }
            let tag = u32::from_le_bytes([b[0], b[1], b[2], b[3]]);
            let msg_id = u32::from_le_bytes([b[4], b[5], b[6], b[7]]);
            let hit = self
                .archive
                .get(&(m.src, tag))
                .filter(|(mid, _)| *mid == msg_id)
                .map(|(_, fs)| fs.clone());
            if let Some(frames) = hit {
                // Retransmissions happen (or not) depending on which
                // faults fired and when — they are not part of the clean
                // send stream, so the audit skips them.
                self.audit_paused = true;
                for f in frames {
                    // Retransmissions re-enter the chaos seam: a retried
                    // frame can be faulted again; the bounded fault budget
                    // (FaultPlan::max_faults) guarantees convergence.
                    self.isend_frame(m.src, tag, f);
                    served += 1;
                }
                self.audit_paused = false;
            }
        }
        self.retransmits_served += served;
        served
    }

    /// Total frames re-published by [`Communicator::service_retry_queue`].
    #[inline]
    pub fn retransmits_served(&self) -> u64 {
        self.retransmits_served
    }

    /// Ask `src` to restart the delta stream on `tag` with a full
    /// refresh. Sent when this rank detected damage it cannot repair by
    /// retransmission (e.g. a delta arrived for a reference the receiver
    /// discarded). Travels on [`tags::RESYNC`], exempt from chaos.
    pub fn request_resync(&mut self, src: u32, tag: Tag) {
        self.isend(src, tags::RESYNC, tag.to_le_bytes().to_vec());
    }

    /// Drain pending resync requests into `out` as `(peer, tag)` pairs.
    /// Malformed payloads are ignored (best-effort control plane).
    pub fn drain_resync_requests(&mut self, out: &mut Vec<(u32, Tag)>) {
        while let Some(m) = self.try_recv(None, Some(tags::RESYNC)) {
            let b = m.data.as_slice();
            if b.len() == 4 {
                out.push((m.src, u32::from_le_bytes([b[0], b[1], b[2], b[3]])));
            }
        }
    }

    /// Broadcast a zero-byte heartbeat to every live peer on
    /// [`tags::HEARTBEAT`]. Bounded receives emit these periodically
    /// while they sit in a long wait, so a stalled-but-alive rank is
    /// never mistaken for a dead one by peers stalled on *it* in turn
    /// (the [`Communicator::overdue`] mailbox probe sees the queued
    /// heartbeat). No-op when liveness is off.
    pub fn send_heartbeats(&mut self) {
        if self.liveness.is_none() {
            return;
        }
        for peer in 0..self.size as u32 {
            if peer != self.rank && !self.is_dead(peer) {
                self.isend(peer, tags::HEARTBEAT, Vec::new());
            }
        }
    }

    /// Tell every live peer that `dead` have been declared dead (one LE
    /// `u32` per rank on [`tags::DEATH`]). Ranks that never wait on the
    /// dead peers directly learn of the death through this notice and
    /// run the same reshard path. No-op when liveness is off.
    pub fn announce_dead(&mut self, dead: &[u32]) {
        if self.liveness.is_none() || dead.is_empty() {
            return;
        }
        let mut payload = Vec::with_capacity(dead.len() * 4);
        for &d in dead {
            payload.extend_from_slice(&d.to_le_bytes());
        }
        for peer in 0..self.size as u32 {
            if peer != self.rank && !self.is_dead(peer) {
                self.isend(peer, tags::DEATH, payload.clone());
            }
        }
    }

    /// Drain the liveness control plane: heartbeats are discarded (their
    /// receipt already refreshed the sender's silence clock) and death
    /// notices mark their subjects dead, pushing ranks not previously
    /// known dead into `newly_dead` (ascending, deduplicated). Malformed
    /// or self-referential notices are ignored.
    pub fn drain_control_liveness(&mut self, newly_dead: &mut Vec<u32>) {
        while self.try_recv(None, Some(tags::HEARTBEAT)).is_some() {}
        while let Some(m) = self.try_recv(None, Some(tags::DEATH)) {
            for c in m.data.as_slice().chunks_exact(4) {
                let r = u32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                if (r as usize) < self.size && r != self.rank && !self.is_dead(r) {
                    self.mark_dead(r);
                    newly_dead.push(r);
                }
            }
        }
        newly_dead.sort_unstable();
        newly_dead.dedup();
    }

    /// Non-blocking send of an owned vector (completes immediately
    /// in-process; no copy — the vector is published as an owned
    /// [`Frame`]).
    pub fn isend(&mut self, dst: u32, tag: Tag, data: Vec<u8>) {
        self.isend_frame(dst, tag, Frame::owned(data));
    }

    /// Scatter-gather send: stage `parts` into one pooled frame (the
    /// analog of an MPI derived datatype / `IOV`-style send, with the
    /// single staging copy modeling the DMA write into the shared
    /// segment). No allocation in steady state — the frame buffer is
    /// recycled from the world's [`FramePool`]. Callers that already own
    /// a publishable buffer should use [`Communicator::isend_frame`]
    /// instead and skip the copy entirely.
    pub fn isend_parts(&mut self, dst: u32, tag: Tag, parts: &[&[u8]]) {
        let mut frame = self.transport.frame_pool().take();
        let total: usize = parts.iter().map(|p| p.len()).sum();
        frame.as_mut_vec().reserve(total);
        for p in parts {
            frame.extend_from_slice(p);
        }
        self.isend_frame(dst, tag, frame.seal());
    }

    /// Probe: is a matching message available? (src/tag `None` = ANY).
    /// Probing never moves the fairness cursor.
    pub fn probe(&self, src: Option<u32>, tag: Option<Tag>) -> Option<(u32, Tag, usize)> {
        self.mailbox.peek(src, tag)
    }

    /// Non-blocking matched receive. ANY-source matching rotates the
    /// per-source fairness cursor (see [`MailboxCore`]).
    pub fn try_recv(&mut self, src: Option<u32>, tag: Option<Tag>) -> Option<RecvMsg> {
        let m = self.mailbox.try_take(src, tag)?;
        Self::note_heard(&mut self.liveness, m.src);
        Some(m)
    }

    /// The one blocking-receive loop every bounded and unbounded receive
    /// runs through. Slices the wait by the transport's
    /// [`poll_interval`](Transport::poll_interval) and pumps between
    /// slices, so a backend with pending nonblocking sends keeps making
    /// progress while this rank is blocked — the completion-latency bound
    /// (a queued send completes within one slice, ≤ the poll interval,
    /// even if the rank never sends again) and the deadlock-avoidance for
    /// mutually-blocked real-process ranks.
    fn recv_inner(
        &mut self,
        src: Option<u32>,
        tag: Option<Tag>,
        timeout: Option<Duration>,
    ) -> Result<(RecvMsg, f64), CommError> {
        if let Some(m) = self.mailbox.try_take(src, tag) {
            Self::note_heard(&mut self.liveness, m.src);
            return Ok((m, 0.0));
        }
        let err_tag = tag.unwrap_or(0);
        let start = Instant::now();
        loop {
            self.transport.pump();
            let remaining = match timeout {
                Some(t) => match t.checked_sub(start.elapsed()) {
                    Some(r) => Some(r),
                    None => {
                        return Err(CommError::Timeout {
                            tag: err_tag,
                            waited_secs: start.elapsed().as_secs_f64(),
                        })
                    }
                },
                None => None,
            };
            // Cap the sleep at the transport's poll interval so pending
            // sends are pumped even during an unbounded receive.
            let slice = match (self.transport.poll_interval(), remaining) {
                (None, r) => r,
                (Some(p), None) => Some(p),
                (Some(p), Some(r)) => Some(p.min(r)),
            };
            if let Some(m) = self.mailbox.take_or_wait(src, tag, slice) {
                Self::note_heard(&mut self.liveness, m.src);
                return Ok((m, start.elapsed().as_secs_f64()));
            }
            if self.mailbox.is_closed() {
                // Shutdown: nothing more will ever arrive.
                return Err(CommError::Timeout {
                    tag: err_tag,
                    waited_secs: start.elapsed().as_secs_f64(),
                });
            }
        }
    }

    /// Blocking matched receive.
    ///
    /// Blocks forever if the message never arrives — use
    /// [`Communicator::recv_any_deadline`] (or reliable batched receive)
    /// on paths that must survive loss.
    pub fn recv(&mut self, src: Option<u32>, tag: Option<Tag>) -> RecvMsg {
        match self.recv_inner(src, tag, None) {
            Ok((m, _)) => m,
            Err(e) => panic!("unbounded recv failed: {e} (mailbox closed under a blocking recv)"),
        }
    }

    /// Blocking receive of the next message with `tag` from **any**
    /// source, reporting separately the wall-clock seconds actually spent
    /// blocked (`0.0` when a matching message was already queued — the
    /// `MPI_Probe`-hit case). This is the completion-aware receive the
    /// overlapped aura ingest runs on: frames are consumed in *arrival*
    /// order (fairness-rotated across sources) instead of a fixed source
    /// order, and the blocked wait is measurable on its own so the engine
    /// can keep transport wait out of its CPU-time op buckets (the
    /// receive-side clock-skew fix).
    pub fn recv_any_timed(&mut self, tag: Tag) -> (RecvMsg, f64) {
        match self.recv_inner(None, Some(tag), None) {
            Ok(out) => out,
            Err(e) => panic!("unbounded recv failed: {e} (mailbox closed under a blocking recv)"),
        }
    }

    /// Bounded version of [`Communicator::recv_any_timed`]: block for at
    /// most `timeout` for the next message with `tag` from any source.
    /// Returns the message plus the seconds actually spent blocked, or
    /// [`CommError::Timeout`] — the rank keeps running either way, which
    /// is what turns a lost frame from a deadlock into a recoverable
    /// event.
    pub fn recv_any_deadline(
        &mut self,
        tag: Tag,
        timeout: Duration,
    ) -> Result<(RecvMsg, f64), CommError> {
        self.recv_inner(None, Some(tag), Some(timeout))
    }

    /// Cancel (drain) all pending messages with `tag` — the paper's
    /// "obsolete speculative receives are cancelled" after rebalancing.
    pub fn cancel_pending(&mut self, tag: Tag) -> usize {
        self.mailbox.cancel(tag)
    }

    /// Barrier over all ranks. Backend-native when available; otherwise
    /// synthesized from an empty allgather (a full synchronization point
    /// over plain sends).
    pub fn barrier(&mut self) {
        if self.transport.native_barrier() {
            return;
        }
        let _ = self.allgather(Vec::new());
    }

    /// All-gather: every rank contributes `data`, returns all
    /// contributions indexed by rank. Ranks must call collectives in the
    /// same order (standard MPI contract). Runs the backend's native
    /// rendezvous when it has one; otherwise a gather-to-root +
    /// length-prefixed broadcast over plain sends (root = lowest rank not
    /// known dead), with the same liveness escalation as `alltoallv`.
    pub fn allgather(&mut self, data: Vec<u8>) -> Vec<Vec<u8>> {
        let size = self.size;
        // Simulated cost: ring allgather moves (size-1) messages per rank.
        if size > 1 {
            self.network_secs += self.network.transfer_secs(data.len()) * (size - 1) as f64;
        }
        if let Some(all) = self.transport.native_allgather(&data) {
            return all;
        }
        self.p2p_allgather(&data)
    }

    /// The p2p collective fallback: gather to the lowest live rank, then
    /// broadcast the combined `[len u32][bytes] × size` payload back.
    /// Legs travel on per-round [`tags::COLLECTIVE_BASE`] tags (control
    /// plane: exempt from chaos and the stream audit, sent raw so the
    /// upfront ring charge in [`Communicator::allgather`] is the only
    /// network cost). Waits are sliced so retry requests keep being
    /// served, heartbeats flow during long waits, and a peer that dies
    /// mid-collective is overdue-escalated instead of hanging the world.
    fn p2p_allgather(&mut self, data: &[u8]) -> Vec<Vec<u8>> {
        let size = self.size;
        let round = self.collective_round;
        self.collective_round += 1;
        if size == 1 {
            return vec![data.to_vec()];
        }
        let gtag = tags::collective_gather(round);
        let btag = tags::collective_bcast(round);
        const SLICE: Duration = Duration::from_millis(25);
        let root = (0..size as u32).find(|r| !self.is_dead(*r)).unwrap_or(0);
        if self.rank == root {
            let mut parts: Vec<Option<Vec<u8>>> = vec![None; size];
            parts[self.rank as usize] = Some(data.to_vec());
            for d in self.dead_ranks() {
                if parts[d as usize].is_none() {
                    parts[d as usize] = Some(Vec::new());
                }
            }
            let mut empty_slices = 0u32;
            while parts.iter().any(|p| p.is_none()) {
                if self.reliable {
                    self.service_retry_queue();
                }
                match self.recv_inner(None, Some(gtag), Some(SLICE)) {
                    Ok((m, _)) => {
                        if parts[m.src as usize].is_none() {
                            parts[m.src as usize] = Some(m.data.to_vec());
                        }
                    }
                    Err(_) => {
                        empty_slices += 1;
                        if empty_slices % 32 == 0 {
                            self.send_heartbeats();
                        }
                        let pending: Vec<u32> = parts
                            .iter()
                            .enumerate()
                            .filter(|(_, p)| p.is_none())
                            .map(|(i, _)| i as u32)
                            .collect();
                        for d in self.overdue(&pending) {
                            self.mark_dead(d);
                            if parts[d as usize].is_none() {
                                parts[d as usize] = Some(Vec::new());
                            }
                        }
                    }
                }
            }
            let mut combined = Vec::new();
            for p in parts.iter() {
                let p = p.as_ref().expect("loop exits only once all parts are present");
                combined.extend_from_slice(&(p.len() as u32).to_le_bytes());
                combined.extend_from_slice(p);
            }
            for peer in 0..size as u32 {
                if peer != self.rank && !self.is_dead(peer) {
                    self.transport.send(peer, btag, Frame::owned(combined.clone()));
                }
            }
            parts.into_iter().map(|p| p.expect("all parts present")).collect()
        } else {
            self.transport.send(root, gtag, Frame::owned(data.to_vec()));
            let mut empty_slices = 0u32;
            loop {
                if self.reliable {
                    self.service_retry_queue();
                }
                match self.recv_inner(Some(root), Some(btag), Some(SLICE)) {
                    Ok((m, _)) => {
                        return Self::parse_combined(m.data.as_slice(), size).unwrap_or_else(
                            || {
                                // Malformed broadcast: degenerate to
                                // own-contribution-only rather than panic
                                // on remote input.
                                let mut out = vec![Vec::new(); size];
                                out[self.rank as usize] = data.to_vec();
                                out
                            },
                        );
                    }
                    Err(_) => {
                        empty_slices += 1;
                        if empty_slices % 32 == 0 {
                            self.send_heartbeats();
                        }
                        if !self.overdue(&[root]).is_empty() {
                            // Root died mid-collective: every slot but our
                            // own degenerates to empty; the recovery
                            // ladder (reshard) takes it from here.
                            self.mark_dead(root);
                            let mut out = vec![Vec::new(); size];
                            out[self.rank as usize] = data.to_vec();
                            return out;
                        }
                    }
                }
            }
        }
    }

    /// Parse a broadcast `[len u32][bytes] × size` payload. `None` on any
    /// malformed shape (wire input is never trusted).
    fn parse_combined(bytes: &[u8], size: usize) -> Option<Vec<Vec<u8>>> {
        let mut out = Vec::with_capacity(size);
        let mut off = 0usize;
        for _ in 0..size {
            let hdr_end = off.checked_add(4)?;
            let len_bytes: [u8; 4] = bytes.get(off..hdr_end)?.try_into().ok()?;
            let len = u32::from_le_bytes(len_bytes) as usize;
            off = hdr_end;
            let end = off.checked_add(len)?;
            out.push(bytes.get(off..end)?.to_vec());
            off = end;
        }
        (off == bytes.len()).then_some(out)
    }

    /// Sum-allreduce over f64 values ("SumOverAllRanks" of §3.4).
    pub fn allreduce_sum_f64(&mut self, values: &[f64]) -> Vec<f64> {
        let mut bytes = Vec::with_capacity(values.len() * 8);
        for v in values {
            bytes.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        let all = self.allgather(bytes);
        let mut out = vec![0.0; values.len()];
        for contrib in all {
            for (i, chunk) in contrib.chunks_exact(8).enumerate() {
                let bytes: [u8; 8] = chunk.try_into().expect("chunks_exact yields 8 bytes");
                out[i] += f64::from_bits(u64::from_le_bytes(bytes));
            }
        }
        out
    }

    /// Sum-allreduce over u64 counters.
    pub fn allreduce_sum_u64(&mut self, values: &[u64]) -> Vec<u64> {
        let mut bytes = Vec::with_capacity(values.len() * 8);
        for v in values {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let all = self.allgather(bytes);
        let mut out = vec![0u64; values.len()];
        for contrib in all {
            for (i, chunk) in contrib.chunks_exact(8).enumerate() {
                let bytes: [u8; 8] = chunk.try_into().expect("chunks_exact yields 8 bytes");
                out[i] += u64::from_le_bytes(bytes);
            }
        }
        out
    }

    /// Max-allreduce over one f64.
    pub fn allreduce_max_f64(&mut self, value: f64) -> f64 {
        let all = self.allgather(value.to_bits().to_le_bytes().to_vec());
        all.iter()
            .map(|b| {
                let bytes: [u8; 8] = b[..8].try_into().expect("allgather preserves length");
                f64::from_bits(u64::from_le_bytes(bytes))
            })
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// All-to-all variable: `per_dst[d]` goes to rank `d`; returns the
    /// messages received, indexed by source (the agent-migration /
    /// collective-lookup primitive).
    ///
    /// `round` disambiguates successive exchanges: ranks are NOT barrier-
    /// synchronized between iterations, so a fast rank's round-`r+1`
    /// message may arrive while a slow rank is still collecting round `r`.
    /// The round is folded into the message tag, so mismatched messages
    /// simply wait in the mailbox.
    ///
    /// Every payload travels in an integrity envelope —
    /// `[round u32][crc32(payload) u32] ++ payload` — so in-flight damage
    /// (chaos truncate/bit-flip on the per-round alltoall tags) is
    /// detected on receive instead of corrupting the decode. In reliable
    /// mode the sender archives each envelope; a receiver that sees a
    /// damaged or missing message NACKs on [`tags::RETRY`] and the
    /// archived frame is re-published, same ladder as the batched
    /// exchange. Duplicates (chaos or a retransmission racing its
    /// original) are dropped by the filled-slot check.
    pub fn alltoallv(&mut self, per_dst: Vec<Vec<u8>>, round: u32) -> Vec<Vec<u8>> {
        assert_eq!(per_dst.len(), self.size);
        let tag = tags::alltoall_round(round);
        // Alltoall tags are unique per round, so the latest-per-channel
        // archive replacement never fires for them: evict prior rounds
        // explicitly or the archive grows with the iteration count.
        if self.reliable {
            self.archive.retain(|&(_, t), _| {
                !(tags::ALLTOALL_BASE..tags::COLLECTIVE_BASE).contains(&t) || t == tag
            });
        }
        let mut out: Vec<Option<Vec<u8>>> = vec![None; self.size];
        let mut received = 0;
        // Peers already declared dead contribute nothing: skip the send
        // (the mailbox of an exited rank is never drained) and pre-fill
        // their slot with an empty payload so the receive loop terminates.
        for d in self.dead_ranks() {
            out[d as usize] = Some(Vec::new());
            received += 1;
        }
        for (d, data) in per_dst.into_iter().enumerate() {
            if out[d].is_some() {
                continue; // dead peer
            }
            let crc = {
                let t0 = Instant::now();
                let crc = Crc32::new().update(&data).finalize();
                self.checksum_secs += t0.elapsed().as_secs_f64();
                crc
            };
            let mut envelope = Vec::with_capacity(8 + data.len());
            envelope.extend_from_slice(&round.to_le_bytes());
            envelope.extend_from_slice(&crc.to_le_bytes());
            envelope.extend_from_slice(&data);
            let frame = Frame::owned(envelope);
            if d as u32 == self.rank {
                // Local loopback: every backend delivers a self-send
                // straight into the own mailbox, off the wire and without
                // network charge.
                self.transport.send(self.rank, tag, frame);
            } else {
                // Archive before publishing (refcount clone): a NACK can
                // arrive any time after the faulted original was dropped.
                self.archive_frames(d as u32, tag, round, vec![frame.clone()]);
                self.isend_frame(d as u32, tag, frame);
            }
        }
        let mut idle_slices = 0u32;
        while received < self.size {
            // In reliable mode, keep serving retransmission requests while
            // blocked: a peer stuck in its (chaos-afflicted) aura receive
            // may be NACKing us, and we must answer or the whole world
            // deadlocks on this collective.
            let m = if self.reliable {
                let mut got = None;
                while got.is_none() && received < self.size {
                    self.service_retry_queue();
                    match self.recv_any_deadline(tag, Duration::from_millis(1)) {
                        Ok((m, _)) => got = Some(m),
                        Err(_) => {
                            idle_slices += 1;
                            let pending: Vec<u32> = out
                                .iter()
                                .enumerate()
                                .filter(|(_, o)| o.is_none())
                                .map(|(i, _)| i as u32)
                                .collect();
                            // A dropped envelope leaves its source silent
                            // forever: after a few empty slices, NACK every
                            // still-missing live source. Sources that have
                            // not reached this round yet ignore the request
                            // (archive miss) and send normally later.
                            if idle_slices % 4 == 0 {
                                for &s in &pending {
                                    if s != self.rank && !self.is_dead(s) {
                                        self.request_retry(s, tag, round);
                                        self.a2a_nacks += 1;
                                    }
                                }
                            }
                            // A peer that died *mid-collective* would hang
                            // this loop forever: once the liveness plane
                            // says a still-missing source is overdue,
                            // declare it dead and take an empty payload in
                            // its place.
                            for d in self.overdue(&pending) {
                                self.mark_dead(d);
                                if out[d as usize].is_none() {
                                    out[d as usize] = Some(Vec::new());
                                    received += 1;
                                }
                            }
                        }
                    }
                }
                match got {
                    Some(m) => m,
                    None => continue,
                }
            } else {
                self.recv(None, Some(tag))
            };
            let src = m.src as usize;
            let bytes = m.data.as_slice();
            let intact = bytes.len() >= 8
                && u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]) == round
                && {
                    let want = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]);
                    let t0 = Instant::now();
                    let got = Crc32::new().update(&bytes[8..]).finalize();
                    self.checksum_secs += t0.elapsed().as_secs_f64();
                    got == want
                };
            if !intact {
                // Damaged in flight. Reliable mode NACKs and waits for the
                // archived envelope; outside reliable mode nothing can
                // damage a frame, so this is a protocol violation.
                assert!(
                    self.reliable,
                    "corrupt alltoallv envelope from {} outside reliable mode",
                    m.src
                );
                self.a2a_rejects += 1;
                if out[src].is_none() && !self.is_dead(m.src) {
                    self.request_retry(m.src, tag, round);
                    self.a2a_nacks += 1;
                }
                continue;
            }
            if out[src].is_some() {
                // A chaos duplicate, a retransmission whose original was
                // merely late, or a pre-death frame racing the empty
                // placeholder of a peer we gave up on. Outside reliable
                // mode only the death race is possible.
                assert!(
                    self.reliable || self.is_dead(m.src),
                    "duplicate alltoallv message from {}",
                    m.src
                );
                continue;
            }
            // Strip the envelope in place: `into_vec` moves the buffer out
            // without copying when it is uniquely held (the steady state).
            let mut payload = m.data.into_vec();
            payload.drain(..8);
            out[src] = Some(payload);
            received += 1;
        }
        out.into_iter()
            .map(|o| o.expect("received == size implies every slot filled"))
            .collect()
    }

    /// alltoallv envelopes rejected on receive (CRC/round/shape damage).
    #[inline]
    pub fn alltoall_rejects(&self) -> u64 {
        self.a2a_rejects
    }

    /// NACKs sent from the alltoallv receive loop (missing or damaged
    /// envelopes).
    #[inline]
    pub fn alltoall_nacks(&self) -> u64 {
        self.a2a_nacks
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn spawn_ranks<F>(size: usize, f: F) -> Vec<thread::JoinHandle<()>>
    where
        F: Fn(Communicator) + Send + Sync + 'static,
    {
        let world = MpiWorld::new(size, NetworkModel::ideal());
        let f = Arc::new(f);
        (0..size)
            .map(|r| {
                let comm = world.communicator(r as u32);
                let f = Arc::clone(&f);
                thread::spawn(move || f(comm))
            })
            .collect()
    }

    fn join(hs: Vec<thread::JoinHandle<()>>) {
        for h in hs {
            h.join().unwrap();
        }
    }

    #[test]
    fn point_to_point_send_recv() {
        join(spawn_ranks(2, |mut c| {
            if c.rank() == 0 {
                c.isend(1, tags::AURA, vec![1, 2, 3]);
            } else {
                let m = c.recv(Some(0), Some(tags::AURA));
                assert_eq!(&m.data[..], [1, 2, 3]);
                assert_eq!(m.src, 0);
            }
        }));
    }

    #[test]
    fn isend_parts_concatenates() {
        join(spawn_ranks(2, |mut c| {
            if c.rank() == 0 {
                c.isend_parts(1, tags::AURA, &[&[1, 2], &[], &[3, 4, 5]]);
            } else {
                let m = c.recv(Some(0), Some(tags::AURA));
                assert_eq!(&m.data[..], [1, 2, 3, 4, 5]);
            }
        }));
    }

    #[test]
    fn probe_and_try_recv() {
        join(spawn_ranks(2, |mut c| {
            if c.rank() == 0 {
                c.isend(1, tags::MIGRATION, vec![9; 100]);
            } else {
                // Spin until probe sees it.
                loop {
                    if let Some((src, tag, len)) = c.probe(None, None) {
                        assert_eq!((src, tag, len), (0, tags::MIGRATION, 100));
                        break;
                    }
                    std::thread::yield_now();
                }
                let m = c.try_recv(Some(0), Some(tags::MIGRATION)).unwrap();
                assert_eq!(m.data.len(), 100);
                assert!(c.try_recv(None, None).is_none());
            }
        }));
    }

    #[test]
    fn recv_any_timed_takes_arrival_order_and_times_only_the_wait() {
        join(spawn_ranks(3, |mut c| {
            match c.rank() {
                0 => {
                    c.barrier(); // both senders' messages are queued
                    let (m1, w1) = c.recv_any_timed(tags::AURA);
                    let (m2, w2) = c.recv_any_timed(tags::AURA);
                    // Queued messages: no blocking, zero wait reported.
                    assert_eq!(w1, 0.0);
                    assert_eq!(w2, 0.0);
                    let mut srcs = [m1.src, m2.src];
                    srcs.sort();
                    assert_eq!(srcs, [1, 2]);
                    // Now block on a message that arrives late (rank 1
                    // holds it until we signal, then sleeps past our
                    // entry into the wait).
                    c.isend(1, tags::CONTROL, vec![0]);
                    let (m3, w3) = c.recv_any_timed(tags::MIGRATION);
                    assert_eq!(&m3.data[..], [9]);
                    assert!(w3 > 0.0, "blocked wait must be measured");
                }
                1 => {
                    c.isend(0, tags::AURA, vec![1]);
                    c.barrier();
                    c.recv(Some(0), Some(tags::CONTROL));
                    std::thread::sleep(std::time::Duration::from_millis(20));
                    c.isend(0, tags::MIGRATION, vec![9]);
                }
                _ => {
                    c.isend(0, tags::AURA, vec![2]);
                    c.barrier();
                }
            }
        }));
    }

    #[test]
    fn tag_matching_is_selective() {
        join(spawn_ranks(2, |mut c| {
            if c.rank() == 0 {
                c.isend(1, tags::AURA, vec![1]);
                c.isend(1, tags::MIGRATION, vec![2]);
            } else {
                // Receive MIGRATION first although AURA arrived first.
                let m = c.recv(None, Some(tags::MIGRATION));
                assert_eq!(&m.data[..], [2]);
                let a = c.recv(None, Some(tags::AURA));
                assert_eq!(&a.data[..], [1]);
            }
        }));
    }

    #[test]
    fn cancel_pending_drops_messages() {
        join(spawn_ranks(2, |mut c| {
            if c.rank() == 0 {
                c.isend(1, tags::AURA, vec![1]);
                c.isend(1, tags::AURA, vec![2]);
                c.isend(1, tags::CONTROL, vec![3]);
                c.barrier();
            } else {
                c.barrier(); // ensure all sends arrived
                let dropped = c.cancel_pending(tags::AURA);
                assert_eq!(dropped, 2);
                let m = c.try_recv(None, None).unwrap();
                assert_eq!(m.tag, tags::CONTROL);
            }
        }));
    }

    #[test]
    fn allgather_collects_all() {
        join(spawn_ranks(4, |mut c| {
            let all = c.allgather(vec![c.rank() as u8; 3]);
            assert_eq!(all.len(), 4);
            for (r, d) in all.iter().enumerate() {
                assert_eq!(d, &vec![r as u8; 3]);
            }
        }));
    }

    #[test]
    fn allgather_repeated_rounds() {
        join(spawn_ranks(3, |mut c| {
            for round in 0..20u8 {
                let all = c.allgather(vec![c.rank() as u8, round]);
                for (r, d) in all.iter().enumerate() {
                    assert_eq!(d, &vec![r as u8, round], "round {round}");
                }
            }
        }));
    }

    #[test]
    fn allreduce_sums() {
        join(spawn_ranks(4, |mut c| {
            let sums = c.allreduce_sum_f64(&[1.0, c.rank() as f64]);
            assert_eq!(sums[0], 4.0);
            assert_eq!(sums[1], 0.0 + 1.0 + 2.0 + 3.0);
            let us = c.allreduce_sum_u64(&[10]);
            assert_eq!(us[0], 40);
            let mx = c.allreduce_max_f64(c.rank() as f64);
            assert_eq!(mx, 3.0);
        }));
    }

    #[test]
    fn alltoallv_exchanges() {
        join(spawn_ranks(3, |mut c| {
            let me = c.rank();
            let per_dst: Vec<Vec<u8>> = (0..3).map(|d| vec![me as u8, d as u8]).collect();
            let got = c.alltoallv(per_dst, 7);
            assert_eq!(got.len(), 3);
            for (src, d) in got.iter().enumerate() {
                assert_eq!(d, &vec![src as u8, me as u8]);
            }
        }));
    }

    #[test]
    fn network_time_is_charged() {
        let world = MpiWorld::new(2, NetworkModel::gige());
        let mut c0 = world.communicator(0);
        let mut c1 = world.communicator(1);
        c0.isend(1, tags::AURA, vec![0; 125_000]); // 1 Mbit -> ~1 ms + latency
        let m = c1.recv(Some(0), None);
        assert_eq!(m.data.len(), 125_000);
        assert!(c0.network_secs > 0.0009, "network_secs = {}", c0.network_secs);
        assert_eq!(world.total_messages.load(Ordering::Relaxed), 1);
        assert_eq!(world.total_wire_bytes.load(Ordering::Relaxed), 125_000);
    }

    #[test]
    fn isend_frame_publishes_the_senders_bytes_in_place() {
        // The receiver must see the very buffer the sender sealed — the
        // zero-copy contract, asserted by pointer identity (valid
        // in-process: ranks share one address space).
        let world = MpiWorld::new(2, NetworkModel::ideal());
        let mut tx = world.communicator(0);
        let mut rx = world.communicator(1);
        let mut buf = world.frame_pool().take();
        buf.extend_from_slice(b"zero-copy wire");
        let frame = buf.seal();
        let sent_ptr = frame.as_slice().as_ptr();
        tx.isend_frame(1, tags::AURA, frame);
        let m = rx.recv(Some(0), Some(tags::AURA));
        assert_eq!(&m.data[..], *b"zero-copy wire");
        assert_eq!(m.data.as_slice().as_ptr(), sent_ptr, "mailbox must not copy the frame");
        drop(m);
        let stats = world.frame_pool().stats();
        assert_eq!(stats.outstanding, 0);
        assert_eq!(stats.free, 1, "dropped frame must recycle");
    }

    #[test]
    fn frame_pool_circulates_buffers_without_growth() {
        let world = MpiWorld::new(2, NetworkModel::ideal());
        let mut tx = world.communicator(0);
        let mut rx = world.communicator(1);
        for round in 0u8..20 {
            tx.isend_parts(1, tags::AURA, &[&[round], &[round, round]]);
            let m = rx.recv(Some(0), Some(tags::AURA));
            assert_eq!(&m.data[..], [round, round, round]);
        }
        let stats = world.frame_pool().stats();
        assert_eq!(stats.outstanding, 0, "no frame may leak");
        assert_eq!(stats.created, 1, "one in-flight message needs one buffer");
        assert_eq!(stats.free, 1);
        assert_eq!(stats.high_water, 1);
        assert_eq!(stats.recycled, 20);
    }

    #[test]
    fn frame_clones_share_bytes_and_recycle_once() {
        let pool = FramePool::new();
        let mut buf = pool.take();
        buf.extend_from_slice(&[7; 32]);
        let a = buf.seal();
        let b = a.clone();
        assert_eq!(a.as_slice().as_ptr(), b.as_slice().as_ptr());
        drop(a);
        assert_eq!(pool.stats().free, 0, "buffer still referenced");
        assert_eq!(pool.stats().outstanding, 1);
        drop(b);
        let stats = pool.stats();
        assert_eq!((stats.free, stats.outstanding, stats.recycled), (1, 0, 1));
    }

    #[test]
    fn unsealed_lease_returns_to_the_pool() {
        let pool = FramePool::new();
        {
            let mut buf = pool.take();
            buf.extend_from_slice(&[1, 2, 3]);
            // Dropped unsealed (e.g. an aborted send).
        }
        assert_eq!(pool.stats().free, 1);
        // into_vec on a unique frame steals the buffer (no recycle).
        let stolen = pool.take().seal().into_vec();
        assert!(stolen.is_empty());
        let stats = pool.stats();
        assert_eq!(stats.outstanding, 0);
        assert_eq!(stats.free, 0, "into_vec transfers ownership out of the pool");
    }

    #[test]
    fn recv_any_deadline_times_out_instead_of_hanging() {
        let world = MpiWorld::new(2, NetworkModel::ideal());
        let mut c = world.communicator(0);
        let t0 = Instant::now();
        let err = c.recv_any_deadline(tags::AURA, Duration::from_millis(10)).unwrap_err();
        assert!(t0.elapsed() >= Duration::from_millis(10));
        match err {
            CommError::Timeout { tag, waited_secs } => {
                assert_eq!(tag, tags::AURA);
                assert!(waited_secs >= 0.009, "waited_secs = {waited_secs}");
            }
            other => panic!("expected Timeout, got {other:?}"),
        }
        // A queued message is returned immediately with zero wait.
        let mut tx = world.communicator(1);
        tx.isend(0, tags::AURA, vec![5]);
        let (m, w) = c.recv_any_deadline(tags::AURA, Duration::from_millis(10)).unwrap();
        assert_eq!(&m.data[..], [5]);
        assert_eq!(w, 0.0);
    }

    #[test]
    fn sequence_counters_are_monotone_per_channel() {
        let world = MpiWorld::new(3, NetworkModel::ideal());
        let mut c = world.communicator(0);
        assert_eq!(c.next_seq(1, tags::AURA), 0);
        assert_eq!(c.next_seq(1, tags::AURA), 1);
        assert_eq!(c.next_seq(2, tags::AURA), 0, "channels are independent");
        assert_eq!(c.next_seq(1, tags::MIGRATION), 0, "tags are independent");
        assert_eq!(c.next_seq(1, tags::AURA), 2);
    }

    #[test]
    fn retry_queue_retransmits_archived_frames() {
        let world = MpiWorld::new(2, NetworkModel::ideal());
        let mut tx = world.communicator(0);
        let mut rx = world.communicator(1);
        tx.set_reliable(true);
        let frame = Frame::owned(vec![1, 2, 3]);
        tx.archive_frames(1, tags::AURA, 7, vec![frame]);
        // Wrong msg_id: no retransmission.
        rx.request_retry(0, tags::AURA, 99);
        assert_eq!(tx.service_retry_queue(), 0);
        // Matching request: the archived frame is re-published.
        rx.request_retry(0, tags::AURA, 7);
        assert_eq!(tx.service_retry_queue(), 1);
        let m = rx.recv(Some(0), Some(tags::AURA));
        assert_eq!(&m.data[..], [1, 2, 3]);
        assert_eq!(tx.retransmits_served(), 1);
        // Malformed retry payloads are ignored, not panicked on.
        tx.isend(0, tags::RETRY, vec![1, 2, 3]);
        assert_eq!(rx.service_retry_queue(), 0);
    }

    #[test]
    fn clean_path_has_no_archive_or_chaos_overhead() {
        let world = MpiWorld::new(2, NetworkModel::ideal());
        let mut tx = world.communicator(0);
        let mut rx = world.communicator(1);
        assert!(!tx.reliable());
        assert_eq!(tx.chaos_stats().injected(), 0);
        // archive_frames is a no-op outside reliable mode: the pooled
        // frame recycles normally and pool stats keep the PR 5 shape.
        let mut buf = world.frame_pool().take();
        buf.extend_from_slice(b"x");
        let f = buf.seal();
        tx.archive_frames(1, tags::AURA, 0, vec![f.clone()]);
        tx.isend_frame(1, tags::AURA, f);
        drop(rx.recv(Some(0), Some(tags::AURA)));
        let stats = world.frame_pool().stats();
        assert_eq!((stats.outstanding, stats.free), (0, 1));
    }

    #[test]
    fn liveness_declares_only_persistently_silent_peers_dead() {
        let world = MpiWorld::new(3, NetworkModel::ideal());
        let mut c = world.communicator(0);
        // Off by default: no peer is ever overdue and nothing is dead.
        assert!(!c.liveness_enabled());
        assert!(c.overdue(&[1, 2]).is_empty());
        assert!(c.dead_ranks().is_empty());
        c.enable_liveness(Duration::from_millis(100));
        // Freshly enabled: everyone counts as just heard from.
        assert!(c.overdue(&[1, 2]).is_empty());
        std::thread::sleep(Duration::from_millis(150));
        // Both silent past the timeout now.
        assert_eq!(c.overdue(&[1, 2]), vec![1, 2]);
        // Rank 1 speaks — any received message re-arms its clock.
        let mut c1 = world.communicator(1);
        c1.isend(0, tags::CONTROL, vec![1]);
        let m = c.recv(Some(1), Some(tags::CONTROL));
        assert_eq!(m.src, 1);
        assert_eq!(c.overdue(&[1, 2]), vec![2]);
        c.mark_dead(2);
        assert!(c.is_dead(2));
        assert!(!c.is_dead(1));
        assert_eq!(c.dead_ranks(), vec![2]);
        // Dead is sticky and reported overdue regardless of timing.
        assert_eq!(c.overdue(&[2]), vec![2]);
    }

    #[test]
    fn alltoallv_substitutes_empty_payloads_for_dead_ranks() {
        // Ranks 0 and 1 run the collective; rank 2 is dead (its thread
        // exits immediately without participating). Rank 0 knows up
        // front; rank 1 discovers it mid-collective via the liveness
        // timeout.
        join(spawn_ranks(3, |mut c| match c.rank() {
            0 => {
                c.set_reliable(true);
                c.enable_liveness(Duration::from_millis(100));
                c.mark_dead(2);
                let got = c.alltoallv(vec![vec![10], vec![20], vec![30]], 3);
                assert_eq!(got[0], vec![10]);
                assert_eq!(got[1], vec![21]);
                assert_eq!(got[2], Vec::<u8>::new(), "dead rank yields empty payload");
            }
            1 => {
                c.set_reliable(true);
                c.enable_liveness(Duration::from_millis(100));
                let got = c.alltoallv(vec![vec![21], vec![22], vec![23]], 3);
                assert_eq!(got[0], vec![20]);
                assert_eq!(got[1], vec![22]);
                assert_eq!(got[2], Vec::<u8>::new());
                assert_eq!(c.dead_ranks(), vec![2], "mid-collective escalation marks the peer");
            }
            _ => {}
        }));
    }

    #[test]
    fn shrink_to_watermark_trims_to_recent_demand() {
        let pool = FramePool::new();
        // Warm-up epoch: 8 frames in flight at once.
        let frames: Vec<Frame> = (0..8)
            .map(|i| {
                let mut b = pool.take();
                b.extend_from_slice(&[i as u8]);
                b.seal()
            })
            .collect();
        drop(frames);
        let stats = pool.stats();
        assert_eq!((stats.free, stats.high_water, stats.created), (8, 8, 8));
        // First trim: peak demand of the ending epoch was 8, so all 8
        // stay parked; the watermark re-arms at the current outstanding.
        assert_eq!(pool.shrink_to_watermark(), 0);
        assert_eq!(pool.stats().free, 8);
        assert_eq!(pool.stats().high_water, 0);
        // Light epoch: only 2 frames ever in flight together.
        for _ in 0..5 {
            let a = pool.take().seal();
            let b = pool.take().seal();
            drop((a, b));
        }
        assert_eq!(pool.stats().high_water, 2);
        // Second trim: keep 2, release 6.
        assert_eq!(pool.shrink_to_watermark(), 6);
        let stats = pool.stats();
        assert_eq!(stats.free, 2);
        assert_eq!(stats.created, 8, "trim releases buffers, it does not create");
        // The survivors still circulate.
        let f = pool.take().seal();
        drop(f);
        assert_eq!(pool.stats().free, 2);
    }

    #[test]
    fn barrier_synchronizes() {
        use std::sync::atomic::AtomicUsize;
        let counter = Arc::new(AtomicUsize::new(0));
        let world = MpiWorld::new(4, NetworkModel::ideal());
        let hs: Vec<_> = (0..4)
            .map(|r| {
                let mut c = world.communicator(r);
                let counter = Arc::clone(&counter);
                thread::spawn(move || {
                    counter.fetch_add(1, Ordering::SeqCst);
                    c.barrier();
                    // After the barrier every increment must be visible.
                    assert_eq!(counter.load(Ordering::SeqCst), 4);
                })
            })
            .collect();
        join(hs);
    }

    /// The p2p collective fallback (the path multiprocess backends run)
    /// must produce the same results as the native condvar rendezvous.
    fn spawn_p2p_ranks<F>(size: usize, f: F) -> Vec<thread::JoinHandle<()>>
    where
        F: Fn(Communicator) + Send + Sync + 'static,
    {
        let world = MpiWorld::new(size, NetworkModel::ideal());
        let f = Arc::new(f);
        (0..size)
            .map(|r| {
                let comm = world.communicator_p2p_collectives(r as u32);
                let f = Arc::clone(&f);
                thread::spawn(move || f(comm))
            })
            .collect()
    }

    #[test]
    fn p2p_allgather_matches_native_results() {
        join(spawn_p2p_ranks(4, |mut c| {
            for round in 0..10u8 {
                // Varying lengths per rank exercise the length-prefixed
                // broadcast framing.
                let mine = vec![c.rank() as u8 ^ round; 1 + c.rank() as usize];
                let all = c.allgather(mine);
                assert_eq!(all.len(), 4);
                for (r, d) in all.iter().enumerate() {
                    assert_eq!(d, &vec![r as u8 ^ round; 1 + r], "round {round}");
                }
            }
        }));
    }

    #[test]
    fn p2p_barrier_and_allreduce() {
        join(spawn_p2p_ranks(3, |mut c| {
            c.barrier();
            let sums = c.allreduce_sum_f64(&[c.rank() as f64]);
            assert_eq!(sums[0], 3.0);
            let mx = c.allreduce_max_f64(c.rank() as f64);
            assert_eq!(mx, 2.0);
            c.barrier();
        }));
    }

    #[test]
    fn p2p_allgather_with_empty_contributions() {
        join(spawn_p2p_ranks(2, |mut c| {
            // Rank 1 contributes nothing — the empty-payload case the
            // synthesized barrier rides on.
            let mine = if c.rank() == 0 { vec![42] } else { Vec::new() };
            let all = c.allgather(mine);
            assert_eq!(all[0], vec![42]);
            assert_eq!(all[1], Vec::<u8>::new());
        }));
    }

    #[test]
    fn parse_combined_rejects_malformed_broadcasts() {
        // Truncated header, truncated payload, trailing garbage.
        assert!(Communicator::parse_combined(&[1, 0, 0], 1).is_none());
        assert!(Communicator::parse_combined(&[5, 0, 0, 0, 1, 2], 1).is_none());
        assert!(Communicator::parse_combined(&[1, 0, 0, 0, 9, 7], 1).is_none());
        let ok = Communicator::parse_combined(&[2, 0, 0, 0, 8, 9, 0, 0, 0, 0], 2).unwrap();
        assert_eq!(ok, vec![vec![8, 9], Vec::new()]);
    }

    #[test]
    fn stream_audit_digests_clean_sends_and_skips_control_traffic() {
        let world = MpiWorld::new(2, NetworkModel::ideal());
        let mut a = world.communicator(0);
        let mut b = world.communicator(0); // same rank: independent handle
        a.enable_stream_audit();
        b.enable_stream_audit();
        assert_eq!(a.stream_audit_crc(), b.stream_audit_crc(), "empty streams agree");
        a.isend(1, tags::AURA, vec![1, 2, 3]);
        b.isend(1, tags::AURA, vec![1, 2, 3]);
        assert_eq!(a.stream_audit_crc(), b.stream_audit_crc(), "same stream, same digest");
        // Control-plane traffic must not perturb the digest.
        let before = a.stream_audit_crc();
        a.isend(1, tags::HEARTBEAT, Vec::new());
        a.request_retry(1, tags::AURA, 3);
        assert_eq!(a.stream_audit_crc(), before);
        // A diverging data-plane send must.
        a.isend(1, tags::AURA, vec![9]);
        b.isend(1, tags::AURA, vec![8]);
        assert_ne!(a.stream_audit_crc(), b.stream_audit_crc());
    }

    #[test]
    fn stream_audit_ignores_retransmissions() {
        let world = MpiWorld::new(2, NetworkModel::ideal());
        let mut tx = world.communicator(0);
        let mut rx = world.communicator(1);
        tx.set_reliable(true);
        tx.enable_stream_audit();
        tx.isend(1, tags::AURA, vec![5; 16]);
        let clean = tx.stream_audit_crc();
        tx.archive_frames(1, tags::AURA, 0, vec![Frame::owned(vec![5; 16])]);
        rx.request_retry(0, tags::AURA, 0);
        assert_eq!(tx.service_retry_queue(), 1);
        assert_eq!(tx.stream_audit_crc(), clean, "retransmission must not shift the digest");
    }

    #[test]
    fn recv_any_round_robins_across_flooding_sources() {
        // Rank 1 floods 50 frames; rank 2 sends one. The ANY-source
        // receive must serve rank 2 within the first two takes instead of
        // draining the flood first (the recv_any fairness fix).
        let world = MpiWorld::new(3, NetworkModel::ideal());
        let mut rx = world.communicator(0);
        let mut flood = world.communicator(1);
        let mut quiet = world.communicator(2);
        for i in 0..50u8 {
            flood.isend(0, tags::AURA, vec![i]);
        }
        quiet.isend(0, tags::AURA, b"quiet".to_vec());
        let first = rx.recv_any_timed(tags::AURA).0;
        let second = rx.recv_any_timed(tags::AURA).0;
        let srcs = [first.src, second.src];
        assert!(srcs.contains(&2), "quiet source starved: first two takes came from {srcs:?}");
    }

    #[test]
    fn transport_counters_track_remote_data_plane_sends() {
        let world = MpiWorld::new(2, NetworkModel::ideal());
        let mut c = world.communicator(0);
        assert_eq!(c.transport_kind(), TransportKind::InProcess);
        c.isend(1, tags::AURA, vec![0; 10]);
        c.isend(0, tags::AURA, vec![0; 4]); // loopback: off the wire
        assert_eq!(c.wire_messages_sent, 1);
        assert_eq!(c.wire_bytes_sent, 10);
        let ts = c.transport_stats();
        assert_eq!((ts.frames_sent, ts.bytes_sent), (1, 10));
        assert_eq!(c.send_inflight(), 0);
        assert_eq!(c.pump(), 0);
    }
}
