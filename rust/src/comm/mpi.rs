//! In-process simulated MPI.
//!
//! Semantics follow the subset of MPI the engine needs (§2.4.3):
//! non-blocking point-to-point (`isend` / `try_recv` ≈ `MPI_Isend` +
//! `MPI_Probe`/`MPI_Irecv`), blocking matched receive, barrier, and the
//! collectives (`allgather`, `allreduce`, `alltoallv`) used by
//! distributed initialization, load balancing and result reduction.
//!
//! Each rank owns a [`Communicator`] handle; mailboxes are per-rank
//! mutex-protected queues with condvar wakeups. Message payloads are
//! opaque byte vectors — all typing happens in the serialization layer,
//! exactly as with real MPI buffers. Every transfer is charged simulated
//! network seconds per the configured [`NetworkModel`].

use super::network::NetworkModel;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Message tag. The engine uses distinct tags per protocol step.
pub type Tag = u32;

/// Well-known tags.
pub mod tags {
    use super::Tag;
    pub const AURA: Tag = 1;
    pub const MIGRATION: Tag = 2;
    pub const BALANCE: Tag = 3;
    pub const CONTROL: Tag = 4;
    pub const CHUNK: Tag = 5;
    /// Per-round all-to-all tags live above this base.
    pub const ALLTOALL_BASE: Tag = 0x4000_0000;

    /// Tag for the all-to-all exchange of `round`.
    pub fn alltoall_round(round: u32) -> Tag {
        ALLTOALL_BASE + round
    }
}

/// A received message.
#[derive(Debug, Clone)]
pub struct RecvMsg {
    pub src: u32,
    pub tag: Tag,
    pub data: Vec<u8>,
}

#[derive(Debug)]
struct Envelope {
    src: u32,
    tag: Tag,
    data: Vec<u8>,
}

#[derive(Debug, Default)]
struct Mailbox {
    queue: VecDeque<Envelope>,
}

/// One collective rendezvous slot.
#[derive(Debug, Default)]
struct CollectiveSlot {
    round: u64,
    deposits: Vec<Option<Vec<u8>>>,
    /// Count of ranks that picked up the result of the current round.
    collected: usize,
    results: Option<Vec<Vec<u8>>>,
}

/// Shared world state.
pub struct MpiWorld {
    size: usize,
    mailboxes: Vec<(Mutex<Mailbox>, Condvar)>,
    barrier: std::sync::Barrier,
    collective: Mutex<CollectiveSlot>,
    collective_cv: Condvar,
    network: NetworkModel,
    /// Total wire bytes moved (all ranks).
    pub total_wire_bytes: AtomicU64,
    /// Total messages.
    pub total_messages: AtomicU64,
}

impl MpiWorld {
    /// Create a world with `size` ranks over the given network model.
    pub fn new(size: usize, network: NetworkModel) -> Arc<MpiWorld> {
        assert!(size >= 1);
        Arc::new(MpiWorld {
            size,
            mailboxes: (0..size).map(|_| (Mutex::new(Mailbox::default()), Condvar::new())).collect(),
            barrier: std::sync::Barrier::new(size),
            collective: Mutex::new(CollectiveSlot {
                round: 0,
                deposits: vec![None; size],
                collected: 0,
                results: None,
            }),
            collective_cv: Condvar::new(),
            network,
            total_wire_bytes: AtomicU64::new(0),
            total_messages: AtomicU64::new(0),
        })
    }

    /// Handle for `rank`.
    pub fn communicator(self: &Arc<Self>, rank: u32) -> Communicator {
        assert!((rank as usize) < self.size);
        Communicator { world: Arc::clone(self), rank, network_secs: 0.0 }
    }

    pub fn size(&self) -> usize {
        self.size
    }
}

/// Per-rank communicator handle.
pub struct Communicator {
    world: Arc<MpiWorld>,
    rank: u32,
    /// Simulated network seconds charged to this rank.
    pub network_secs: f64,
}

impl Communicator {
    #[inline]
    pub fn rank(&self) -> u32 {
        self.rank
    }

    #[inline]
    pub fn size(&self) -> usize {
        self.world.size
    }

    /// Non-blocking send (completes immediately in-process; the network
    /// model charges the simulated wire time to the sender).
    pub fn isend(&mut self, dst: u32, tag: Tag, data: Vec<u8>) {
        assert!((dst as usize) < self.world.size, "invalid destination rank {dst}");
        let bytes = data.len();
        self.network_secs += self.world.network.transfer_secs(bytes);
        self.world.total_wire_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        self.world.total_messages.fetch_add(1, Ordering::Relaxed);
        let (lock, cv) = &self.world.mailboxes[dst as usize];
        let mut mb = lock.lock().unwrap();
        mb.queue.push_back(Envelope { src: self.rank, tag, data });
        cv.notify_all();
    }

    /// Scatter-gather send: assemble `parts` into a single message with
    /// one exact-size allocation (the analog of an MPI derived datatype /
    /// `IOV`-style send). The batching layer frames chunk headers around
    /// caller-owned wire buffers with this, so encode → send performs no
    /// intermediate copy of the payload besides the one into the mailbox
    /// message itself.
    pub fn isend_parts(&mut self, dst: u32, tag: Tag, parts: &[&[u8]]) {
        let total: usize = parts.iter().map(|p| p.len()).sum();
        let mut data = Vec::with_capacity(total);
        for p in parts {
            data.extend_from_slice(p);
        }
        self.isend(dst, tag, data);
    }

    /// Probe: is a matching message available? (src/tag `None` = ANY).
    pub fn probe(&self, src: Option<u32>, tag: Option<Tag>) -> Option<(u32, Tag, usize)> {
        let (lock, _) = &self.world.mailboxes[self.rank as usize];
        let mb = lock.lock().unwrap();
        mb.queue
            .iter()
            .find(|e| src.map_or(true, |s| e.src == s) && tag.map_or(true, |t| e.tag == t))
            .map(|e| (e.src, e.tag, e.data.len()))
    }

    /// Non-blocking matched receive.
    pub fn try_recv(&mut self, src: Option<u32>, tag: Option<Tag>) -> Option<RecvMsg> {
        let (lock, _) = &self.world.mailboxes[self.rank as usize];
        let mut mb = lock.lock().unwrap();
        let idx = mb
            .queue
            .iter()
            .position(|e| src.map_or(true, |s| e.src == s) && tag.map_or(true, |t| e.tag == t))?;
        let e = mb.queue.remove(idx).unwrap();
        Some(RecvMsg { src: e.src, tag: e.tag, data: e.data })
    }

    /// Blocking matched receive.
    pub fn recv(&mut self, src: Option<u32>, tag: Option<Tag>) -> RecvMsg {
        let (lock, cv) = &self.world.mailboxes[self.rank as usize];
        let mut mb = lock.lock().unwrap();
        loop {
            if let Some(idx) = mb
                .queue
                .iter()
                .position(|e| src.map_or(true, |s| e.src == s) && tag.map_or(true, |t| e.tag == t))
            {
                let e = mb.queue.remove(idx).unwrap();
                return RecvMsg { src: e.src, tag: e.tag, data: e.data };
            }
            mb = cv.wait(mb).unwrap();
        }
    }

    /// Blocking receive of the next message with `tag` from **any**
    /// source, reporting separately the wall-clock seconds actually spent
    /// blocked (`0.0` when a matching message was already queued — the
    /// `MPI_Probe`-hit case). This is the completion-aware receive the
    /// overlapped aura ingest runs on: frames are consumed in *arrival*
    /// order instead of a fixed source order, and the blocked wait is
    /// measurable on its own so the engine can keep transport wait out of
    /// its CPU-time op buckets (the receive-side clock-skew fix).
    pub fn recv_any_timed(&mut self, tag: Tag) -> (RecvMsg, f64) {
        let (lock, cv) = &self.world.mailboxes[self.rank as usize];
        let mut mb = lock.lock().unwrap();
        if let Some(idx) = mb.queue.iter().position(|e| e.tag == tag) {
            let e = mb.queue.remove(idx).unwrap();
            return (RecvMsg { src: e.src, tag: e.tag, data: e.data }, 0.0);
        }
        let start = std::time::Instant::now();
        loop {
            mb = cv.wait(mb).unwrap();
            if let Some(idx) = mb.queue.iter().position(|e| e.tag == tag) {
                let e = mb.queue.remove(idx).unwrap();
                let waited = start.elapsed().as_secs_f64();
                return (RecvMsg { src: e.src, tag: e.tag, data: e.data }, waited);
            }
        }
    }

    /// Cancel (drain) all pending messages with `tag` — the paper's
    /// "obsolete speculative receives are cancelled" after rebalancing.
    pub fn cancel_pending(&mut self, tag: Tag) -> usize {
        let (lock, _) = &self.world.mailboxes[self.rank as usize];
        let mut mb = lock.lock().unwrap();
        let before = mb.queue.len();
        mb.queue.retain(|e| e.tag != tag);
        before - mb.queue.len()
    }

    /// Barrier over all ranks.
    pub fn barrier(&self) {
        self.world.barrier.wait();
    }

    /// All-gather: every rank contributes `data`, returns all
    /// contributions indexed by rank. Ranks must call collectives in the
    /// same order (standard MPI contract).
    pub fn allgather(&mut self, data: Vec<u8>) -> Vec<Vec<u8>> {
        let size = self.world.size;
        let bytes = data.len();
        // Simulated cost: ring allgather moves (size-1) messages per rank.
        if size > 1 {
            self.network_secs += self.world.network.transfer_secs(bytes) * (size - 1) as f64;
        }
        let mut slot = self.world.collective.lock().unwrap();
        let my_round = slot.round;
        slot.deposits[self.rank as usize] = Some(data);
        if slot.deposits.iter().all(|d| d.is_some()) {
            // Last depositor publishes results and advances the round.
            let results: Vec<Vec<u8>> =
                slot.deposits.iter_mut().map(|d| d.take().unwrap()).collect();
            slot.results = Some(results);
            slot.collected = 0;
            self.world.collective_cv.notify_all();
        } else {
            while slot.results.is_none() || slot.round != my_round {
                slot = self.world.collective_cv.wait(slot).unwrap();
                if slot.round != my_round {
                    break;
                }
            }
        }
        let out = slot.results.as_ref().expect("collective results missing").clone();
        slot.collected += 1;
        if slot.collected == size {
            slot.results = None;
            slot.round += 1;
            self.world.collective_cv.notify_all();
        } else {
            // Wait for round completion to prevent a fast rank from
            // entering the next collective early and clobbering deposits.
            while slot.round == my_round && slot.results.is_some() {
                slot = self.world.collective_cv.wait(slot).unwrap();
            }
        }
        out
    }

    /// Sum-allreduce over f64 values ("SumOverAllRanks" of §3.4).
    pub fn allreduce_sum_f64(&mut self, values: &[f64]) -> Vec<f64> {
        let mut bytes = Vec::with_capacity(values.len() * 8);
        for v in values {
            bytes.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        let all = self.allgather(bytes);
        let mut out = vec![0.0; values.len()];
        for contrib in all {
            for (i, chunk) in contrib.chunks_exact(8).enumerate() {
                out[i] += f64::from_bits(u64::from_le_bytes(chunk.try_into().unwrap()));
            }
        }
        out
    }

    /// Sum-allreduce over u64 counters.
    pub fn allreduce_sum_u64(&mut self, values: &[u64]) -> Vec<u64> {
        let mut bytes = Vec::with_capacity(values.len() * 8);
        for v in values {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let all = self.allgather(bytes);
        let mut out = vec![0u64; values.len()];
        for contrib in all {
            for (i, chunk) in contrib.chunks_exact(8).enumerate() {
                out[i] += u64::from_le_bytes(chunk.try_into().unwrap());
            }
        }
        out
    }

    /// Max-allreduce over one f64.
    pub fn allreduce_max_f64(&mut self, value: f64) -> f64 {
        let all = self.allgather(value.to_bits().to_le_bytes().to_vec());
        all.iter()
            .map(|b| f64::from_bits(u64::from_le_bytes(b[..8].try_into().unwrap())))
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// All-to-all variable: `per_dst[d]` goes to rank `d`; returns the
    /// messages received, indexed by source (the agent-migration /
    /// collective-lookup primitive).
    ///
    /// `round` disambiguates successive exchanges: ranks are NOT barrier-
    /// synchronized between iterations, so a fast rank's round-`r+1`
    /// message may arrive while a slow rank is still collecting round `r`.
    /// The round is folded into the message tag, so mismatched messages
    /// simply wait in the mailbox.
    pub fn alltoallv(&mut self, per_dst: Vec<Vec<u8>>, round: u32) -> Vec<Vec<u8>> {
        assert_eq!(per_dst.len(), self.world.size);
        let tag = tags::alltoall_round(round);
        for (d, data) in per_dst.into_iter().enumerate() {
            if d as u32 == self.rank {
                // Local loopback: deliver directly without network charge.
                let (lock, cv) = &self.world.mailboxes[d];
                let mut mb = lock.lock().unwrap();
                mb.queue.push_back(Envelope { src: self.rank, tag, data });
                cv.notify_all();
            } else {
                self.isend(d as u32, tag, data);
            }
        }
        let mut out: Vec<Option<Vec<u8>>> = vec![None; self.world.size];
        let mut received = 0;
        while received < self.world.size {
            let m = self.recv(None, Some(tag));
            assert!(out[m.src as usize].is_none(), "duplicate alltoallv message from {}", m.src);
            out[m.src as usize] = Some(m.data);
            received += 1;
        }
        out.into_iter().map(|o| o.unwrap()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn spawn_ranks<F>(size: usize, f: F) -> Vec<thread::JoinHandle<()>>
    where
        F: Fn(Communicator) + Send + Sync + 'static,
    {
        let world = MpiWorld::new(size, NetworkModel::ideal());
        let f = Arc::new(f);
        (0..size)
            .map(|r| {
                let comm = world.communicator(r as u32);
                let f = Arc::clone(&f);
                thread::spawn(move || f(comm))
            })
            .collect()
    }

    fn join(hs: Vec<thread::JoinHandle<()>>) {
        for h in hs {
            h.join().unwrap();
        }
    }

    #[test]
    fn point_to_point_send_recv() {
        join(spawn_ranks(2, |mut c| {
            if c.rank() == 0 {
                c.isend(1, tags::AURA, vec![1, 2, 3]);
            } else {
                let m = c.recv(Some(0), Some(tags::AURA));
                assert_eq!(m.data, vec![1, 2, 3]);
                assert_eq!(m.src, 0);
            }
        }));
    }

    #[test]
    fn isend_parts_concatenates() {
        join(spawn_ranks(2, |mut c| {
            if c.rank() == 0 {
                c.isend_parts(1, tags::AURA, &[&[1, 2], &[], &[3, 4, 5]]);
            } else {
                let m = c.recv(Some(0), Some(tags::AURA));
                assert_eq!(m.data, vec![1, 2, 3, 4, 5]);
            }
        }));
    }

    #[test]
    fn probe_and_try_recv() {
        join(spawn_ranks(2, |mut c| {
            if c.rank() == 0 {
                c.isend(1, tags::MIGRATION, vec![9; 100]);
            } else {
                // Spin until probe sees it.
                loop {
                    if let Some((src, tag, len)) = c.probe(None, None) {
                        assert_eq!((src, tag, len), (0, tags::MIGRATION, 100));
                        break;
                    }
                    std::thread::yield_now();
                }
                let m = c.try_recv(Some(0), Some(tags::MIGRATION)).unwrap();
                assert_eq!(m.data.len(), 100);
                assert!(c.try_recv(None, None).is_none());
            }
        }));
    }

    #[test]
    fn recv_any_timed_takes_arrival_order_and_times_only_the_wait() {
        join(spawn_ranks(3, |mut c| {
            match c.rank() {
                0 => {
                    c.barrier(); // both senders' messages are queued
                    let (m1, w1) = c.recv_any_timed(tags::AURA);
                    let (m2, w2) = c.recv_any_timed(tags::AURA);
                    // Queued messages: no blocking, zero wait reported.
                    assert_eq!(w1, 0.0);
                    assert_eq!(w2, 0.0);
                    let mut srcs = [m1.src, m2.src];
                    srcs.sort();
                    assert_eq!(srcs, [1, 2]);
                    // Now block on a message that arrives late (rank 1
                    // holds it until we signal, then sleeps past our
                    // entry into the wait).
                    c.isend(1, tags::CONTROL, vec![0]);
                    let (m3, w3) = c.recv_any_timed(tags::MIGRATION);
                    assert_eq!(m3.data, vec![9]);
                    assert!(w3 > 0.0, "blocked wait must be measured");
                }
                1 => {
                    c.isend(0, tags::AURA, vec![1]);
                    c.barrier();
                    c.recv(Some(0), Some(tags::CONTROL));
                    std::thread::sleep(std::time::Duration::from_millis(20));
                    c.isend(0, tags::MIGRATION, vec![9]);
                }
                _ => {
                    c.isend(0, tags::AURA, vec![2]);
                    c.barrier();
                }
            }
        }));
    }

    #[test]
    fn tag_matching_is_selective() {
        join(spawn_ranks(2, |mut c| {
            if c.rank() == 0 {
                c.isend(1, tags::AURA, vec![1]);
                c.isend(1, tags::MIGRATION, vec![2]);
            } else {
                // Receive MIGRATION first although AURA arrived first.
                let m = c.recv(None, Some(tags::MIGRATION));
                assert_eq!(m.data, vec![2]);
                let a = c.recv(None, Some(tags::AURA));
                assert_eq!(a.data, vec![1]);
            }
        }));
    }

    #[test]
    fn cancel_pending_drops_messages() {
        join(spawn_ranks(2, |mut c| {
            if c.rank() == 0 {
                c.isend(1, tags::AURA, vec![1]);
                c.isend(1, tags::AURA, vec![2]);
                c.isend(1, tags::CONTROL, vec![3]);
                c.barrier();
            } else {
                c.barrier(); // ensure all sends arrived
                let dropped = c.cancel_pending(tags::AURA);
                assert_eq!(dropped, 2);
                let m = c.try_recv(None, None).unwrap();
                assert_eq!(m.tag, tags::CONTROL);
            }
        }));
    }

    #[test]
    fn allgather_collects_all() {
        join(spawn_ranks(4, |mut c| {
            let all = c.allgather(vec![c.rank() as u8; 3]);
            assert_eq!(all.len(), 4);
            for (r, d) in all.iter().enumerate() {
                assert_eq!(d, &vec![r as u8; 3]);
            }
        }));
    }

    #[test]
    fn allgather_repeated_rounds() {
        join(spawn_ranks(3, |mut c| {
            for round in 0..20u8 {
                let all = c.allgather(vec![c.rank() as u8, round]);
                for (r, d) in all.iter().enumerate() {
                    assert_eq!(d, &vec![r as u8, round], "round {round}");
                }
            }
        }));
    }

    #[test]
    fn allreduce_sums() {
        join(spawn_ranks(4, |mut c| {
            let sums = c.allreduce_sum_f64(&[1.0, c.rank() as f64]);
            assert_eq!(sums[0], 4.0);
            assert_eq!(sums[1], 0.0 + 1.0 + 2.0 + 3.0);
            let us = c.allreduce_sum_u64(&[10]);
            assert_eq!(us[0], 40);
            let mx = c.allreduce_max_f64(c.rank() as f64);
            assert_eq!(mx, 3.0);
        }));
    }

    #[test]
    fn alltoallv_exchanges() {
        join(spawn_ranks(3, |mut c| {
            let me = c.rank();
            let per_dst: Vec<Vec<u8>> = (0..3).map(|d| vec![me as u8, d as u8]).collect();
            let got = c.alltoallv(per_dst, 7);
            assert_eq!(got.len(), 3);
            for (src, d) in got.iter().enumerate() {
                assert_eq!(d, &vec![src as u8, me as u8]);
            }
        }));
    }

    #[test]
    fn network_time_is_charged() {
        let world = MpiWorld::new(2, NetworkModel::gige());
        let mut c0 = world.communicator(0);
        let mut c1 = world.communicator(1);
        c0.isend(1, tags::AURA, vec![0; 125_000]); // 1 Mbit -> ~1 ms + latency
        let m = c1.recv(Some(0), None);
        assert_eq!(m.data.len(), 125_000);
        assert!(c0.network_secs > 0.0009, "network_secs = {}", c0.network_secs);
        assert_eq!(world.total_messages.load(Ordering::Relaxed), 1);
        assert_eq!(world.total_wire_bytes.load(Ordering::Relaxed), 125_000);
    }

    #[test]
    fn barrier_synchronizes() {
        use std::sync::atomic::AtomicUsize;
        let counter = Arc::new(AtomicUsize::new(0));
        let world = MpiWorld::new(4, NetworkModel::ideal());
        let hs: Vec<_> = (0..4)
            .map(|r| {
                let c = world.communicator(r);
                let counter = Arc::clone(&counter);
                thread::spawn(move || {
                    counter.fetch_add(1, Ordering::SeqCst);
                    c.barrier();
                    // After the barrier every increment must be visible.
                    assert_eq!(counter.load(Ordering::SeqCst), 4);
                })
            })
            .collect();
        join(hs);
    }
}
