//! Deterministic fault injection at the frame boundary (the chaos
//! transport).
//!
//! A [`FaultPlan`] describes, per outgoing link, the probability of each
//! fault class — drop, delay, duplicate, reorder, truncate, bit-flip —
//! plus a hard budget ([`FaultPlan::max_faults`]) after which the link
//! behaves perfectly. [`ChaosState`] applies the plan at the
//! [`Communicator::isend_frame`](super::mpi::Communicator::isend_frame)
//! seam, *below* the batching layer and *above* the mailbox: faults hit
//! real pooled [`Frame`](super::mpi::Frame)s mid-lifecycle, so the
//! recovery machinery is exercised against the same refcount/recycle
//! discipline the clean path runs.
//!
//! Determinism: every decision draws from a per-destination
//! [`Rng`] stream seeded from `(plan.seed, src, dst)`. A rank's send
//! sequence is deterministic (the engine is), so the exact set of
//! injected faults is a pure function of the seed — the chaos
//! convergence suite pins seeds and asserts bit-identical recovery.
//!
//! Fault semantics:
//! - **drop** — the frame never reaches the mailbox (recycles
//!   immediately; the receiver recovers it via NACK + retransmit).
//! - **delay** / **reorder** — the frame is *held* and released right
//!   after the next frame published on the same `(dst, tag)` link, i.e.
//!   it arrives late and out of order. (In a mailbox transport a delay
//!   that preserves order is unobservable; the one-frame swap is the
//!   minimal observable form of both faults, counted separately.)
//! - **duplicate** — two references to the same frame are published; the
//!   receiver must detect and drop the second copy.
//! - **truncate** — a shortened *copy* is published (the sender's pooled
//!   bytes are never mutated — other clones may still be archived).
//! - **bit-flip** — a copy with one random bit inverted is published.
//!
//! At most one fault applies per frame, chosen by a single uniform draw
//! against the cumulative probabilities.

use super::mpi::{Frame, Tag};
use crate::util::Rng;
use std::collections::HashMap;

/// Per-link fault probabilities and scope. All probabilities are
/// independent per frame; their sum must be ≤ 1 (validated on install).
#[derive(Clone, Debug)]
pub struct FaultPlan {
    /// Seed for the per-link decision streams.
    pub seed: u64,
    pub p_drop: f64,
    pub p_delay: f64,
    pub p_duplicate: f64,
    pub p_reorder: f64,
    pub p_truncate: f64,
    pub p_bit_flip: f64,
    /// Tags subject to injection. Only checksummed, retransmittable
    /// streams (the batched exchange tags) should be listed; control
    /// tags are exempt regardless.
    pub tags: Vec<Tag>,
    /// Hard cap on total injected faults — guarantees that retry loops
    /// converge (after the budget is spent the link is perfect).
    pub max_faults: u64,
    /// Deterministic rank death: the first frame on an eligible tag whose
    /// leading `msg_id` word (the engine stamps the iteration there for
    /// aura traffic) reaches this iteration marks the sender dead, and
    /// every frame after that — any tag — is swallowed. Unlike the
    /// transient faults above, death is permanent: it ignores
    /// [`FaultPlan::max_faults`] and never heals, so the peers' only way
    /// out is the liveness → reshard ladder. The engine also consults
    /// this field directly (`Communicator::chaos_plan`) to stop the
    /// victim's iteration loop at the same boundary.
    pub kill_at_iteration: Option<u64>,
}

impl FaultPlan {
    /// A plan that injects nothing (useful as a builder base).
    pub fn none(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            p_drop: 0.0,
            p_delay: 0.0,
            p_duplicate: 0.0,
            p_reorder: 0.0,
            p_truncate: 0.0,
            p_bit_flip: 0.0,
            tags: vec![super::mpi::tags::AURA],
            max_faults: u64::MAX,
            kill_at_iteration: None,
        }
    }

    pub fn with_drop(mut self, p: f64) -> FaultPlan {
        self.p_drop = p;
        self
    }

    pub fn with_delay(mut self, p: f64) -> FaultPlan {
        self.p_delay = p;
        self
    }

    pub fn with_duplicate(mut self, p: f64) -> FaultPlan {
        self.p_duplicate = p;
        self
    }

    pub fn with_reorder(mut self, p: f64) -> FaultPlan {
        self.p_reorder = p;
        self
    }

    pub fn with_truncate(mut self, p: f64) -> FaultPlan {
        self.p_truncate = p;
        self
    }

    pub fn with_bit_flip(mut self, p: f64) -> FaultPlan {
        self.p_bit_flip = p;
        self
    }

    pub fn with_max_faults(mut self, n: u64) -> FaultPlan {
        self.max_faults = n;
        self
    }

    pub fn with_tags(mut self, tags: Vec<Tag>) -> FaultPlan {
        self.tags = tags;
        self
    }

    /// Kill the owning rank once its eligible traffic reaches
    /// `iteration` (see [`FaultPlan::kill_at_iteration`]).
    pub fn with_kill_at_iteration(mut self, iteration: u64) -> FaultPlan {
        self.kill_at_iteration = Some(iteration);
        self
    }

    /// Is `tag` within this plan's fault scope? Listed tags match
    /// exactly; listing [`tags::MIGRATION`](super::mpi::tags::MIGRATION)
    /// additionally covers the whole per-round alltoallv tag range
    /// (`ALLTOALL_BASE + r` — one fresh tag per exchange round, so the
    /// rounds can never be enumerated in the list itself). Control tags
    /// stay exempt through the [`is_control`](super::mpi::tags::is_control)
    /// gate at the send seam, not here.
    pub fn matches_tag(&self, tag: Tag) -> bool {
        use super::mpi::tags;
        self.tags.contains(&tag)
            || ((tags::ALLTOALL_BASE..tags::COLLECTIVE_BASE).contains(&tag)
                && self.tags.contains(&tags::MIGRATION))
    }

    fn total_p(&self) -> f64 {
        self.p_drop
            + self.p_delay
            + self.p_duplicate
            + self.p_reorder
            + self.p_truncate
            + self.p_bit_flip
    }
}

/// Count of faults injected so far, by class.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ChaosStats {
    pub dropped: u64,
    pub delayed: u64,
    pub duplicated: u64,
    pub reordered: u64,
    pub truncated: u64,
    pub bit_flipped: u64,
    /// Frames swallowed after the rank-death trigger fired. Counted
    /// apart from [`ChaosStats::injected`]: death is a permanent state,
    /// not a budgeted link fault, and must never consume the
    /// `max_faults` budget (which would resurrect the rank).
    pub killed: u64,
}

impl ChaosStats {
    /// Total transient faults injected (excludes `killed`; see above).
    pub fn injected(&self) -> u64 {
        self.dropped
            + self.delayed
            + self.duplicated
            + self.reordered
            + self.truncated
            + self.bit_flipped
    }
}

/// The live injector installed on a `Communicator`.
#[derive(Debug)]
pub struct ChaosState {
    plan: FaultPlan,
    /// Per-destination decision stream (keyed by dst; the owning rank is
    /// folded into the seed at creation).
    rngs: HashMap<u32, Rng>,
    /// Frames held back by delay/reorder, per `(dst, tag)` link —
    /// released after the next frame published on that link.
    held: HashMap<(u32, Tag), Vec<Frame>>,
    /// Latched once the rank-death trigger fires; permanent.
    dead: bool,
    stats: ChaosStats,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Fault {
    Drop,
    Delay,
    Duplicate,
    Reorder,
    Truncate,
    BitFlip,
}

impl ChaosState {
    pub fn new(plan: FaultPlan) -> ChaosState {
        assert!(
            plan.total_p() <= 1.0 + 1e-12,
            "fault probabilities must sum to <= 1 (got {})",
            plan.total_p()
        );
        ChaosState {
            plan,
            rngs: HashMap::new(),
            held: HashMap::new(),
            dead: false,
            stats: ChaosStats::default(),
        }
    }

    pub fn stats(&self) -> ChaosStats {
        self.stats
    }

    /// Has the rank-death trigger fired?
    pub fn is_dead(&self) -> bool {
        self.dead
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Route one outgoing frame through the fault plan. Returns the
    /// frames to actually publish, in order (possibly empty: dropped or
    /// held; possibly several: duplicates and released held frames).
    pub fn apply(&mut self, src: u32, dst: u32, tag: Tag, frame: Frame) -> Vec<Frame> {
        // Rank death precedes everything: the trigger is the leading
        // `msg_id` word of an eligible frame reaching the kill
        // iteration, after which no frame leaves this rank again.
        if let Some(kill) = self.plan.kill_at_iteration {
            if !self.dead && self.plan.matches_tag(tag) && frame.len() >= 4 {
                let msg_id =
                    u32::from_le_bytes([frame[0], frame[1], frame[2], frame[3]]);
                if msg_id as u64 >= kill {
                    self.dead = true;
                }
            }
            if self.dead {
                self.stats.killed += 1;
                drop(frame);
                self.held.clear(); // nothing held survives the death either
                return Vec::new();
            }
        }
        // Frames previously held on this link release after the current
        // frame — the observable delay/reorder.
        let prior = self.held.remove(&(dst, tag)).unwrap_or_default();
        let mut out = Vec::with_capacity(2 + prior.len());
        let fault = self.decide(src, dst, tag);
        match fault {
            None => out.push(frame),
            Some(Fault::Drop) => {
                self.stats.dropped += 1;
                drop(frame); // recycles (or frees) immediately
            }
            Some(Fault::Delay) => {
                self.stats.delayed += 1;
                self.held.entry((dst, tag)).or_default().push(frame);
            }
            Some(Fault::Reorder) => {
                self.stats.reordered += 1;
                self.held.entry((dst, tag)).or_default().push(frame);
            }
            Some(Fault::Duplicate) => {
                self.stats.duplicated += 1;
                out.push(frame.clone());
                out.push(frame);
            }
            Some(Fault::Truncate) => {
                self.stats.truncated += 1;
                let rng = self.rng(src, dst);
                let keep = if frame.is_empty() { 0 } else { rng.index(frame.len()) };
                // Publish a shortened copy; never mutate the original
                // bytes (archived clones must stay intact for retries).
                out.push(Frame::owned(frame.as_slice()[..keep].to_vec()));
            }
            Some(Fault::BitFlip) => {
                self.stats.bit_flipped += 1;
                let rng = self.rng(src, dst);
                let mut bytes = frame.to_vec();
                if !bytes.is_empty() {
                    let i = rng.index(bytes.len());
                    let bit = rng.index(8);
                    bytes[i] ^= 1 << bit;
                }
                out.push(Frame::owned(bytes));
            }
        }
        out.extend(prior);
        out
    }

    fn rng(&mut self, src: u32, dst: u32) -> &mut Rng {
        let seed = self.plan.seed;
        self.rngs
            .entry(dst)
            .or_insert_with(|| Rng::stream(seed, ((src as u64) << 32) | dst as u64))
    }

    fn decide(&mut self, src: u32, dst: u32, tag: Tag) -> Option<Fault> {
        if !self.plan.matches_tag(tag) || self.stats.injected() >= self.plan.max_faults {
            return None;
        }
        // One uniform draw against the cumulative distribution. The draw
        // happens for every eligible frame (faulted or not) so the
        // decision stream advances deterministically with the traffic.
        let plan = self.plan.clone();
        let u = self.rng(src, dst).uniform();
        let mut acc = plan.p_drop;
        if u < acc {
            return Some(Fault::Drop);
        }
        acc += plan.p_delay;
        if u < acc {
            return Some(Fault::Delay);
        }
        acc += plan.p_duplicate;
        if u < acc {
            return Some(Fault::Duplicate);
        }
        acc += plan.p_reorder;
        if u < acc {
            return Some(Fault::Reorder);
        }
        acc += plan.p_truncate;
        if u < acc {
            return Some(Fault::Truncate);
        }
        acc += plan.p_bit_flip;
        if u < acc {
            return Some(Fault::BitFlip);
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::mpi::tags;

    fn frame(bytes: &[u8]) -> Frame {
        Frame::owned(bytes.to_vec())
    }

    #[test]
    fn no_faults_passes_through() {
        let mut c = ChaosState::new(FaultPlan::none(1));
        for i in 0..50u8 {
            let out = c.apply(0, 1, tags::AURA, frame(&[i]));
            assert_eq!(out.len(), 1);
            assert_eq!(&out[0][..], [i]);
        }
        assert_eq!(c.stats().injected(), 0);
    }

    #[test]
    fn exempt_tags_never_fault() {
        let mut c = ChaosState::new(FaultPlan::none(1).with_drop(1.0));
        for _ in 0..50 {
            assert_eq!(c.apply(0, 1, tags::MIGRATION, frame(&[1])).len(), 1);
        }
        assert_eq!(c.stats().injected(), 0);
    }

    #[test]
    fn migration_scope_covers_the_alltoall_round_tags() {
        let plan = FaultPlan::none(1).with_tags(vec![tags::MIGRATION]).with_drop(1.0);
        assert!(plan.matches_tag(tags::MIGRATION));
        assert!(plan.matches_tag(tags::alltoall_round(0)));
        assert!(plan.matches_tag(tags::alltoall_round(12345)));
        assert!(!plan.matches_tag(tags::AURA));
        assert!(!plan.matches_tag(tags::collective_gather(0)));
        let mut c = ChaosState::new(plan);
        assert!(c.apply(0, 1, tags::alltoall_round(7), frame(&[1, 2, 3])).is_empty());
        assert_eq!(c.stats().dropped, 1);
        // AURA is not listed: exempt even though MIGRATION widens scope.
        assert_eq!(c.apply(0, 1, tags::AURA, frame(&[1])).len(), 1);
        assert_eq!(c.stats().injected(), 1);
    }

    #[test]
    fn drop_all_drops_all() {
        let mut c = ChaosState::new(FaultPlan::none(2).with_drop(1.0));
        for i in 0..10u8 {
            assert!(c.apply(0, 1, tags::AURA, frame(&[i])).is_empty());
        }
        assert_eq!(c.stats().dropped, 10);
    }

    #[test]
    fn max_faults_caps_injection() {
        let mut c = ChaosState::new(FaultPlan::none(3).with_drop(1.0).with_max_faults(3));
        let mut delivered = 0;
        for i in 0..10u8 {
            delivered += c.apply(0, 1, tags::AURA, frame(&[i])).len();
        }
        assert_eq!(c.stats().dropped, 3);
        assert_eq!(delivered, 7, "after the budget the link is perfect");
    }

    #[test]
    fn reorder_swaps_with_next_frame() {
        let mut c = ChaosState::new(FaultPlan::none(4).with_reorder(1.0).with_max_faults(1));
        let out1 = c.apply(0, 1, tags::AURA, frame(&[1]));
        assert!(out1.is_empty(), "reordered frame is held");
        let out2 = c.apply(0, 1, tags::AURA, frame(&[2]));
        assert_eq!(out2.len(), 2);
        assert_eq!(&out2[0][..], [2], "the newer frame goes first");
        assert_eq!(&out2[1][..], [1], "the held frame releases after it");
        assert_eq!(c.stats().reordered, 1);
    }

    #[test]
    fn duplicate_publishes_the_same_bytes_twice() {
        let mut c = ChaosState::new(FaultPlan::none(5).with_duplicate(1.0).with_max_faults(1));
        let out = c.apply(0, 1, tags::AURA, frame(&[7, 8]));
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].as_slice().as_ptr(), out[1].as_slice().as_ptr(), "clones share bytes");
    }

    #[test]
    fn truncate_and_bit_flip_corrupt_a_copy_not_the_original() {
        let mut c = ChaosState::new(FaultPlan::none(6).with_truncate(1.0).with_max_faults(1));
        let original = frame(&[1, 2, 3, 4, 5, 6, 7, 8]);
        let keep = original.clone();
        let out = c.apply(0, 1, tags::AURA, original);
        assert_eq!(out.len(), 1);
        assert!(out[0].len() < 8);
        assert_eq!(&keep[..], [1, 2, 3, 4, 5, 6, 7, 8], "archived clone intact");

        let mut c = ChaosState::new(FaultPlan::none(7).with_bit_flip(1.0).with_max_faults(1));
        let original = frame(&[0u8; 16]);
        let keep = original.clone();
        let out = c.apply(0, 1, tags::AURA, original);
        assert_eq!(out[0].len(), 16);
        let flipped: u32 = out[0].iter().map(|b| b.count_ones()).sum();
        assert_eq!(flipped, 1, "exactly one bit flipped");
        assert!(keep.iter().all(|&b| b == 0), "archived clone intact");
    }

    #[test]
    fn same_seed_same_fault_sequence() {
        let plan = FaultPlan::none(42).with_drop(0.3).with_duplicate(0.2).with_bit_flip(0.1);
        let run = |plan: FaultPlan| {
            let mut c = ChaosState::new(plan);
            let mut counts = Vec::new();
            for i in 0..200u32 {
                let out = c.apply(0, 1, tags::AURA, frame(&i.to_le_bytes()));
                counts.push(out.len());
            }
            (counts, c.stats())
        };
        let (a, sa) = run(plan.clone());
        let (b, sb) = run(plan);
        assert_eq!(a, b);
        assert_eq!(sa, sb);
        assert!(sa.injected() > 0, "plan must actually inject at these odds");
    }

    #[test]
    fn links_have_independent_streams() {
        let plan = FaultPlan::none(42).with_drop(0.5);
        let mut c = ChaosState::new(plan);
        let mut kept = [0u32; 2];
        for i in 0..100u32 {
            kept[0] += c.apply(0, 1, tags::AURA, frame(&i.to_le_bytes())).len() as u32;
            kept[1] += c.apply(0, 2, tags::AURA, frame(&i.to_le_bytes())).len() as u32;
        }
        assert_ne!(kept[0], 0);
        assert_ne!(kept[1], 0);
        // Not a strict requirement, but with 100 draws at p=0.5 identical
        // outcomes on both links would indicate stream reuse.
        assert!(kept[0] != 100 || kept[1] != 100);
    }

    /// The kill trigger keys off the frame's leading msg_id word:
    /// iterations before the kill pass untouched, the kill iteration and
    /// everything after — any tag — is swallowed, forever.
    #[test]
    fn kill_at_iteration_silences_the_rank_permanently() {
        let mut c = ChaosState::new(FaultPlan::none(8).with_kill_at_iteration(3));
        for iter in 0..3u32 {
            let out = c.apply(0, 1, tags::AURA, frame(&iter.to_le_bytes()));
            assert_eq!(out.len(), 1, "iteration {iter} is before the kill");
        }
        assert!(!c.is_dead());
        assert!(c.apply(0, 1, tags::AURA, frame(&3u32.to_le_bytes())).is_empty());
        assert!(c.is_dead());
        // Dead means dead on every tag, and the budget cannot resurrect.
        assert!(c.apply(0, 1, tags::MIGRATION, frame(&[9])).is_empty());
        assert!(c.apply(0, 2, tags::CONTROL, frame(&[9])).is_empty());
        assert!(c.apply(0, 1, tags::AURA, frame(&0u32.to_le_bytes())).is_empty());
        assert_eq!(c.stats().killed, 4);
        assert_eq!(c.stats().injected(), 0, "death is not a budgeted fault");
    }

    /// Frames held by delay/reorder die with the rank instead of leaking
    /// out after the death boundary.
    #[test]
    fn death_swallows_held_frames() {
        let plan =
            FaultPlan::none(9).with_delay(1.0).with_max_faults(1).with_kill_at_iteration(1);
        let mut c = ChaosState::new(plan);
        assert!(c.apply(0, 1, tags::AURA, frame(&0u32.to_le_bytes())).is_empty(), "held");
        let out = c.apply(0, 1, tags::AURA, frame(&1u32.to_le_bytes()));
        assert!(out.is_empty(), "kill frame and the held frame are both swallowed");
        assert!(c.is_dead());
    }
}
