//! Shared-memory slab transport: one OS process per rank; payload bytes
//! travel through per-rank slab files on tmpfs, only tiny descriptors
//! cross the control sockets.
//!
//! # Layout and lifecycle
//!
//! Every rank owns one slab file (`dir/slab{r}`: [`SLOT_BYTES`] ×
//! [`SLOT_COUNT`], sparse) and a first-fit slot allocator over it. The
//! `FramePool` seal-to-publish discipline maps onto the slab as:
//!
//! 1. **publish** — the sender copies the sealed frame's bytes into a
//!    free extent of its *own* slab (`write_all_at`; the modeled DMA
//!    write) and queues a [`DESC`] record `(tag, offset, len)` on the
//!    control stream to the destination. The pooled frame drops
//!    immediately — the slab extent *is* the in-flight buffer now.
//! 2. **receive** — the destination's reader thread sees the `DESC`,
//!    reads `len` bytes at `offset` from the *sender's* slab
//!    (`read_exact_at`) into a pool-leased buffer, pushes it into the
//!    mailbox, and queues a [`RELEASE`] record back.
//! 3. **recycle** — the sender's reader thread frees the extent when the
//!    `RELEASE` arrives (counted in [`TransportStats::slab_releases`]).
//!
//! **Documented deviation from the shared-header-refcount design:** with
//! no `libc`/`mmap` in this environment the slab cannot hold atomic
//! refcounts that both processes touch; ownership is explicit instead —
//! an extent belongs to the sender until the receiver's `RELEASE` record
//! hands it back. Same invariant (an extent is never reused while the
//! receiver may still read it), different mechanism, and the ordering
//! guarantee is free: the slab write completes before the `DESC` is
//! queued, and the control stream is FIFO.
//!
//! When the slab has no free extent (all slots in flight), the payload
//! falls back to traveling **inline** over the control stream like the
//! UDS backend (counted in [`TransportStats::inline_fallbacks`], never
//! an error) — backpressure degrades throughput, not correctness.
//!
//! The control mesh, nonblocking writes, bounded completion window and
//! reader threads are shared with the UDS backend
//! ([`connect_mesh`](super::uds)); everything above the
//! [`Transport`] seam (CRC/seq framing, chaos, retries, liveness,
//! collectives) is identical across backends by construction.

use super::mpi::{Frame, FramePool, Tag};
use super::transport::{MailboxCore, Transport, TransportKind, TransportStats};
use super::uds::connect_mesh;
use std::collections::VecDeque;
use std::fs::{File, OpenOptions};
use std::io::{ErrorKind, Read, Write};
use std::os::unix::fs::FileExt;
use std::os::unix::net::UnixStream;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Slab slot granularity. One extent = 1+ contiguous slots.
pub const SLOT_BYTES: usize = 16 << 10;
/// Slots per rank slab (total slab: 16 MiB, sparse until touched).
pub const SLOT_COUNT: usize = 1024;

/// Control-record kinds.
const DESC: u8 = 0;
const INLINE: u8 = 1;
const RELEASE: u8 = 2;

/// Completion-window caps (control records are tiny except inline
/// fallbacks, so the byte cap is what matters under fallback pressure).
const WINDOW_RECORDS: usize = 256;
const WINDOW_BYTES: usize = 8 << 20;
const STALL_DEADLINE: Duration = Duration::from_secs(1);
const STALL_SLEEP: Duration = Duration::from_micros(50);
/// Send-side retries (pump + microsleep) for a free extent before the
/// inline fallback kicks in.
const ALLOC_RETRIES: usize = 20;
/// Reader-side sanity cap on one payload length.
const MAX_FRAME_BYTES: usize = 1 << 30;

/// Slab path of `rank` under the rendezvous directory.
pub fn slab_path(dir: &Path, rank: u32) -> PathBuf {
    dir.join(format!("slab{rank}"))
}

/// First-fit extent allocator over the slab's slot bitmap, with a rover
/// so steady-state allocation doesn't rescan freed prefixes every time.
struct SlabAlloc {
    used: Vec<bool>,
    rover: usize,
}

impl SlabAlloc {
    fn new() -> SlabAlloc {
        SlabAlloc { used: vec![false; SLOT_COUNT], rover: 0 }
    }

    /// Allocate `nslots` contiguous slots; returns the first slot index.
    fn alloc(&mut self, nslots: usize) -> Option<usize> {
        if nslots == 0 || nslots > SLOT_COUNT {
            return None;
        }
        let n = self.used.len();
        let mut start = self.rover % n;
        for _ in 0..n {
            // A run reaching past the end can't be contiguous; skip ahead.
            if start + nslots > n {
                start = 0;
            }
            let mut run = 0;
            while run < nslots && !self.used[start + run] {
                run += 1;
            }
            if run == nslots {
                for s in &mut self.used[start..start + nslots] {
                    *s = true;
                }
                self.rover = (start + nslots) % n;
                return Some(start);
            }
            start = (start + run + 1) % n;
        }
        None
    }

    fn free(&mut self, first: usize, nslots: usize) {
        for slot in first..(first + nslots).min(self.used.len()) {
            self.used[slot] = false;
        }
    }
}

fn slots_for(len: usize) -> usize {
    len.div_ceil(SLOT_BYTES).max(1)
}

/// One control record mid-write.
struct PendingRec {
    data: Vec<u8>,
    sent: usize,
}

struct Peer {
    stream: UnixStream,
    queue: VecDeque<PendingRec>,
    queued_bytes: usize,
    closed: bool,
    /// Releases owed to this peer for extents of *its* slab we consumed,
    /// queued by our reader thread and drained into `queue` on pump.
    releases: Arc<Mutex<Vec<(u64, u32)>>>,
}

/// The shared-memory slab backend. See the module docs for the protocol.
pub struct ShmTransport {
    rank: u32,
    size: usize,
    pool: FramePool,
    mailbox: Arc<MailboxCore>,
    peers: Vec<Option<Peer>>,
    readers: Vec<std::thread::JoinHandle<()>>,
    /// Our slab file (writable) and its allocator. The allocator is
    /// shared with our reader threads: they free extents when RELEASE
    /// records arrive.
    own_slab: File,
    own_alloc: Arc<Mutex<SlabAlloc>>,
    slab_releases: Arc<AtomicU64>,
    own_slab_path: PathBuf,
    stats: TransportStats,
    shut: bool,
}

impl ShmTransport {
    /// Create `rank`'s slab, join the control mesh and spawn readers.
    /// Every rank creates its slab *before* touching the mesh, so by the
    /// time any stream is up every peer's slab exists — readers open
    /// them without retries.
    pub fn connect(dir: &Path, rank: u32, size: usize) -> std::io::Result<ShmTransport> {
        assert!((rank as usize) < size);
        let own_slab_path = slab_path(dir, rank);
        let own_slab =
            OpenOptions::new().read(true).write(true).create(true).open(&own_slab_path)?;
        own_slab.set_len((SLOT_BYTES * SLOT_COUNT) as u64)?;

        let pool = FramePool::new();
        let mailbox = Arc::new(MailboxCore::new(size));
        let own_alloc = Arc::new(Mutex::new(SlabAlloc::new()));
        let slab_releases = Arc::new(AtomicU64::new(0));
        let streams = connect_mesh(dir, rank, size)?;

        let mut peers: Vec<Option<Peer>> = (0..size).map(|_| None).collect();
        let mut readers = Vec::with_capacity(size.saturating_sub(1));
        for (src, slot) in streams.into_iter().enumerate() {
            let Some(stream) = slot else { continue };
            // The peer created its slab before joining the mesh; if the
            // file is briefly missing we retry (a crashed peer surfaces
            // through the stream error path instead).
            let peer_slab = open_retry(&slab_path(dir, src as u32))?;
            let releases = Arc::new(Mutex::new(Vec::new()));
            let read_half = stream.try_clone()?;
            readers.push(spawn_reader(ReaderCtx {
                src: src as u32,
                stream: read_half,
                peer_slab,
                pool: pool.clone(),
                mailbox: Arc::clone(&mailbox),
                own_alloc: Arc::clone(&own_alloc),
                releases: Arc::clone(&releases),
                slab_releases: Arc::clone(&slab_releases),
            }));
            stream.set_nonblocking(true)?;
            peers[src] = Some(Peer {
                stream,
                queue: VecDeque::new(),
                queued_bytes: 0,
                closed: false,
                releases,
            });
        }

        Ok(ShmTransport {
            rank,
            size,
            pool,
            mailbox,
            peers,
            readers,
            own_slab,
            own_alloc,
            slab_releases,
            own_slab_path,
            stats: TransportStats::default(),
            shut: false,
        })
    }

    fn enqueue(peer: &mut Peer, data: Vec<u8>) {
        peer.queued_bytes += data.len();
        peer.queue.push_back(PendingRec { data, sent: 0 });
    }

    /// Move reader-queued RELEASE records into the peer's write queue.
    /// Runs on every pump so receivers return extents even when this
    /// rank has nothing of its own to send.
    fn drain_releases(peer: &mut Peer) {
        let pending: Vec<(u64, u32)> =
            std::mem::take(&mut *peer.releases.lock().expect("poisoned release queue"));
        for (off, len) in pending {
            let mut rec = Vec::with_capacity(13);
            rec.push(RELEASE);
            rec.extend_from_slice(&off.to_le_bytes());
            rec.extend_from_slice(&len.to_le_bytes());
            Self::enqueue(peer, rec);
        }
    }

    fn flush_peer(peer: &mut Peer, stats: &mut TransportStats) -> usize {
        if peer.closed {
            return 0;
        }
        let mut completed = 0;
        while let Some(p) = peer.queue.front_mut() {
            while p.sent < p.data.len() {
                match peer.stream.write(&p.data[p.sent..]) {
                    Ok(0) => {
                        Self::close_peer(peer, stats);
                        return completed;
                    }
                    Ok(n) => p.sent += n,
                    Err(e) if e.kind() == ErrorKind::WouldBlock => return completed,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => {
                        Self::close_peer(peer, stats);
                        return completed;
                    }
                }
            }
            let done = peer.queue.pop_front().expect("front_mut() just yielded this entry");
            peer.queued_bytes -= done.data.len();
            completed += 1;
        }
        completed
    }

    fn close_peer(peer: &mut Peer, stats: &mut TransportStats) {
        peer.closed = true;
        stats.frames_dropped_peer_closed += peer.queue.len() as u64;
        peer.queued_bytes = 0;
        peer.queue.clear();
    }

    fn window_full(&self) -> bool {
        let (mut recs, mut bytes) = (0usize, 0usize);
        for p in self.peers.iter().flatten() {
            recs += p.queue.len();
            bytes += p.queued_bytes;
        }
        recs > WINDOW_RECORDS || bytes > WINDOW_BYTES
    }

    /// Reserve an extent and write `payload` into our slab. `None` when
    /// the slab is exhausted or the write failed (callers fall back
    /// inline).
    fn stage_in_slab(&mut self, payload: &[u8]) -> Option<(u64, u32)> {
        let nslots = slots_for(payload.len());
        let mut retries = 0;
        let first = loop {
            let got = self.own_alloc.lock().expect("poisoned slab allocator").alloc(nslots);
            match got {
                Some(f) => break f,
                None => {
                    // Extents free up when RELEASE records arrive on our
                    // reader threads; give them a moment before giving up.
                    retries += 1;
                    if retries > ALLOC_RETRIES {
                        return None;
                    }
                    self.stats.send_stalls += 1;
                    std::thread::sleep(STALL_SLEEP);
                }
            }
        };
        let off = (first * SLOT_BYTES) as u64;
        if self.own_slab.write_all_at(payload, off).is_err() {
            self.own_alloc.lock().expect("poisoned slab allocator").free(first, nslots);
            return None;
        }
        Some((off, payload.len() as u32))
    }
}

fn open_retry(path: &Path) -> std::io::Result<File> {
    let start = Instant::now();
    loop {
        match File::open(path) {
            Ok(f) => return Ok(f),
            Err(e) => {
                if start.elapsed() > Duration::from_secs(30) {
                    return Err(e);
                }
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }
}

struct ReaderCtx {
    src: u32,
    stream: UnixStream,
    peer_slab: File,
    pool: FramePool,
    mailbox: Arc<MailboxCore>,
    own_alloc: Arc<Mutex<SlabAlloc>>,
    releases: Arc<Mutex<Vec<(u64, u32)>>>,
    slab_releases: Arc<AtomicU64>,
}

fn spawn_reader(mut ctx: ReaderCtx) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("shm-rx-{}", ctx.src))
        .spawn(move || {
            let mut kind = [0u8; 1];
            loop {
                if ctx.stream.read_exact(&mut kind).is_err() {
                    return;
                }
                match kind[0] {
                    DESC => {
                        let mut hdr = [0u8; 16]; // tag u32 | off u64 | len u32
                        if ctx.stream.read_exact(&mut hdr).is_err() {
                            return;
                        }
                        let tag = u32::from_le_bytes(hdr[..4].try_into().expect("4 bytes"));
                        let off = u64::from_le_bytes(hdr[4..12].try_into().expect("8 bytes"));
                        let len =
                            u32::from_le_bytes(hdr[12..].try_into().expect("4 bytes")) as usize;
                        if len > MAX_FRAME_BYTES {
                            return;
                        }
                        let mut buf = ctx.pool.take_vec();
                        buf.resize(len, 0);
                        if ctx.peer_slab.read_exact_at(&mut buf, off).is_err() {
                            ctx.pool.recycle_vec(buf);
                            return;
                        }
                        ctx.mailbox.push(ctx.src, tag, ctx.pool.seal(buf));
                        // Hand the extent back; the next pump ships it.
                        ctx.releases
                            .lock()
                            .expect("poisoned release queue")
                            .push((off, len as u32));
                    }
                    INLINE => {
                        let mut hdr = [0u8; 8]; // tag u32 | len u32
                        if ctx.stream.read_exact(&mut hdr).is_err() {
                            return;
                        }
                        let tag = u32::from_le_bytes(hdr[..4].try_into().expect("4 bytes"));
                        let len =
                            u32::from_le_bytes(hdr[4..].try_into().expect("4 bytes")) as usize;
                        if len > MAX_FRAME_BYTES {
                            return;
                        }
                        let mut buf = ctx.pool.take_vec();
                        buf.resize(len, 0);
                        if ctx.stream.read_exact(&mut buf).is_err() {
                            ctx.pool.recycle_vec(buf);
                            return;
                        }
                        ctx.mailbox.push(ctx.src, tag, ctx.pool.seal(buf));
                    }
                    RELEASE => {
                        let mut hdr = [0u8; 12]; // off u64 | len u32
                        if ctx.stream.read_exact(&mut hdr).is_err() {
                            return;
                        }
                        let off = u64::from_le_bytes(hdr[..8].try_into().expect("8 bytes"));
                        let len =
                            u32::from_le_bytes(hdr[8..].try_into().expect("4 bytes")) as usize;
                        let first = (off as usize) / SLOT_BYTES;
                        ctx.own_alloc
                            .lock()
                            .expect("poisoned slab allocator")
                            .free(first, slots_for(len));
                        ctx.slab_releases.fetch_add(1, Ordering::Relaxed);
                    }
                    _ => return, // Corrupt control stream: abandon it.
                }
            }
        })
        .expect("spawning a reader thread")
}

impl Transport for ShmTransport {
    fn kind(&self) -> TransportKind {
        TransportKind::Shm
    }

    fn rank(&self) -> u32 {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    fn frame_pool(&self) -> &FramePool {
        &self.pool
    }

    fn mailbox(&self) -> &Arc<MailboxCore> {
        &self.mailbox
    }

    fn send(&mut self, dst: u32, tag: Tag, frame: Frame) {
        assert!((dst as usize) < self.size);
        if dst == self.rank {
            self.mailbox.push(self.rank, tag, frame);
            return;
        }
        self.stats.frames_sent += 1;
        self.stats.bytes_sent += frame.len() as u64;
        let peer_open =
            self.peers[dst as usize].as_ref().is_some_and(|p| !p.closed);
        if !peer_open {
            self.stats.frames_dropped_peer_closed += 1;
            return;
        }
        // Slab path first; inline only when no extent frees up in time.
        let staged =
            if frame.is_empty() { None } else { self.stage_in_slab(frame.as_slice()) };
        let rec = match staged {
            Some((off, len)) => {
                let mut rec = Vec::with_capacity(17);
                rec.push(DESC);
                rec.extend_from_slice(&tag.to_le_bytes());
                rec.extend_from_slice(&off.to_le_bytes());
                rec.extend_from_slice(&len.to_le_bytes());
                rec
            }
            None => {
                if !frame.is_empty() {
                    self.stats.inline_fallbacks += 1;
                }
                let mut rec = Vec::with_capacity(9 + frame.len());
                rec.push(INLINE);
                rec.extend_from_slice(&tag.to_le_bytes());
                rec.extend_from_slice(&(frame.len() as u32).to_le_bytes());
                rec.extend_from_slice(frame.as_slice());
                rec
            }
        };
        // The payload is in the slab (or copied into the record): the
        // pooled frame recycles as soon as `frame` drops at return.
        {
            let peer = self.peers[dst as usize].as_mut().expect("presence checked above");
            Self::drain_releases(peer);
            Self::enqueue(peer, rec);
            Self::flush_peer(peer, &mut self.stats);
        }
        if self.window_full() {
            let start = Instant::now();
            while self.window_full() && start.elapsed() < STALL_DEADLINE {
                self.stats.send_stalls += 1;
                std::thread::sleep(STALL_SLEEP);
                self.pump();
            }
        }
    }

    fn pump(&mut self) -> usize {
        let mut completed = 0;
        for peer in self.peers.iter_mut().flatten() {
            // Always drain releases, even with an empty send queue: the
            // peer's slab starves otherwise.
            Self::drain_releases(peer);
            completed += Self::flush_peer(peer, &mut self.stats);
        }
        completed
    }

    fn inflight(&self) -> usize {
        self.peers.iter().flatten().map(|p| p.queue.len()).sum()
    }

    fn poll_interval(&self) -> Option<Duration> {
        if self.inflight() > 0 {
            Some(Duration::from_millis(1))
        } else {
            Some(Duration::from_millis(5))
        }
    }

    fn stats(&self) -> TransportStats {
        let mut s = self.stats;
        s.slab_releases = self.slab_releases.load(Ordering::Relaxed);
        s
    }

    fn shutdown(&mut self) {
        if self.shut {
            return;
        }
        self.shut = true;
        let deadline = Instant::now() + Duration::from_secs(2);
        loop {
            let pumped = self.pump();
            if (self.inflight() == 0
                && self.peers.iter().flatten().all(|p| {
                    p.releases.lock().expect("poisoned release queue").is_empty()
                }))
                || Instant::now() >= deadline
            {
                break;
            }
            if pumped == 0 {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        for peer in self.peers.iter_mut().flatten() {
            let _ = peer.stream.shutdown(std::net::Shutdown::Both);
        }
        for h in self.readers.drain(..) {
            let _ = h.join();
        }
        self.mailbox.close();
        // Unlinking doesn't disturb peers still holding the open file.
        let _ = std::fs::remove_file(&self.own_slab_path);
    }
}

impl Drop for ShmTransport {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slab_alloc_first_fit_with_rover() {
        let mut a = SlabAlloc::new();
        let x = a.alloc(4).unwrap();
        let y = a.alloc(2).unwrap();
        assert_ne!(x, y);
        assert!(x + 4 <= y || y + 2 <= x, "extents must not overlap");
        a.free(x, 4);
        // A request larger than any remaining hole fails cleanly.
        assert!(a.alloc(SLOT_COUNT + 1).is_none());
        // Everything freed: a full-slab extent fits again.
        a.free(y, 2);
        assert_eq!(a.alloc(SLOT_COUNT), Some(0));
    }

    #[test]
    fn slab_alloc_exhaustion_and_reuse() {
        let mut a = SlabAlloc::new();
        let mut got = Vec::new();
        while let Some(f) = a.alloc(1) {
            got.push(f);
        }
        assert_eq!(got.len(), SLOT_COUNT);
        assert!(a.alloc(1).is_none());
        a.free(got[7], 1);
        assert_eq!(a.alloc(1), Some(got[7]));
    }

    #[test]
    fn slots_for_rounds_up_and_floors_at_one() {
        assert_eq!(slots_for(0), 1);
        assert_eq!(slots_for(1), 1);
        assert_eq!(slots_for(SLOT_BYTES), 1);
        assert_eq!(slots_for(SLOT_BYTES + 1), 2);
    }
}
