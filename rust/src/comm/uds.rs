//! Unix-domain-socket transport: one OS process per rank, true
//! nonblocking sends with a bounded completion window.
//!
//! # Rendezvous
//!
//! All ranks agree on a rendezvous directory. Rank `r` binds
//! `dir/rank{r}.sock` and accepts one connection from every *higher*
//! rank; it then dials every *lower* rank (retrying while the peer's
//! socket file is still appearing). The first 4 bytes on a dialed
//! connection are the dialer's rank LE — after that, both directions
//! carry only frames. The result is a full mesh of `size·(size-1)/2`
//! streams, each serving one rank pair in both directions.
//!
//! # Framing
//!
//! `[tag u32 LE][len u32 LE][payload len bytes]`. This is *below* the
//! 20-byte CRC/seq frame header of `batching` — the transport moves
//! opaque payloads; integrity, sequencing, retransmission, chaos and
//! liveness all live above the [`Transport`] seam, unchanged from the
//! in-process backend.
//!
//! # Nonblocking sends and the completion window
//!
//! The write half of every stream is nonblocking. [`UdsTransport::send`]
//! enqueues the frame and flushes as far as the socket accepts; the rest
//! drains on subsequent [`UdsTransport::pump`] calls (the communicator
//! pumps in every sliced receive wait and once per engine iteration, so
//! completion latency is bounded by one poll interval even if the rank
//! never sends again). A bounded completion window
//! ([`WINDOW_FRAMES`]/[`WINDOW_BYTES`]) applies backpressure: a send
//! over the window spins pump-with-microsleeps (counted in
//! [`TransportStats::send_stalls`]) for at most [`STALL_DEADLINE`], then
//! accepts the overshoot — sends never block indefinitely, and frames
//! queued to a peer whose connection died are dropped and counted
//! ([`TransportStats::frames_dropped_peer_closed`]), leaving the
//! consequences to the liveness plane.
//!
//! # Receiving
//!
//! One detached reader thread per peer blocks on its stream, leases a
//! buffer from the (per-process) [`FramePool`], reads one frame and
//! pushes it into the shared [`MailboxCore`]. Readers exit on EOF or
//! error; [`UdsTransport::shutdown`] flushes best-effort, shuts the
//! sockets down and joins them.

use super::mpi::{Frame, FramePool, Tag};
use super::transport::{MailboxCore, Transport, TransportKind, TransportStats};
use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Completion-window cap on queued (unflushed) frames per transport.
pub const WINDOW_FRAMES: usize = 64;
/// Completion-window cap on queued (unflushed) payload bytes.
pub const WINDOW_BYTES: usize = 8 << 20;
/// How long an over-window send keeps pumping before accepting the
/// overshoot (sends must never block indefinitely).
const STALL_DEADLINE: Duration = Duration::from_secs(1);
/// Microsleep between pump attempts while the window is full.
const STALL_SLEEP: Duration = Duration::from_micros(50);
/// How long rendezvous keeps retrying a peer that has not bound yet.
const CONNECT_DEADLINE: Duration = Duration::from_secs(30);
/// Reader-side sanity cap on one frame's length: a corrupt stream must
/// not OOM the process (the stream is abandoned instead).
const MAX_FRAME_BYTES: usize = 1 << 30;

/// Socket path of `rank` under the rendezvous directory.
pub fn socket_path(dir: &Path, rank: u32) -> PathBuf {
    dir.join(format!("rank{rank}.sock"))
}

/// Establish the pairwise full mesh for `rank` of `size` under `dir`:
/// bind `rank`'s socket, accept one hello-identified connection from
/// every higher rank (on a helper thread, so mid-mesh ranks dialing each
/// other cannot deadlock), dial every lower rank (retrying while its
/// socket file appears). Returns streams indexed by peer rank, `None` at
/// `rank` itself. Shared by the UDS and shm backends — shm runs the
/// same mesh as its control plane.
pub(crate) fn connect_mesh(
    dir: &Path,
    rank: u32,
    size: usize,
) -> std::io::Result<Vec<Option<UnixStream>>> {
    let mut streams: Vec<Option<UnixStream>> = (0..size).map(|_| None).collect();

    let expect_accepts = size - 1 - rank as usize;
    let listener = if expect_accepts > 0 {
        let path = socket_path(dir, rank);
        let _ = std::fs::remove_file(&path);
        Some(UnixListener::bind(&path)?)
    } else {
        None
    };

    let acceptor = listener.map(|l| {
        std::thread::spawn(move || -> std::io::Result<Vec<(u32, UnixStream)>> {
            let mut got = Vec::with_capacity(expect_accepts);
            for _ in 0..expect_accepts {
                let (mut s, _) = l.accept()?;
                let mut hello = [0u8; 4];
                s.read_exact(&mut hello)?;
                got.push((u32::from_le_bytes(hello), s));
            }
            Ok(got)
        })
    });

    for peer in 0..rank {
        let path = socket_path(dir, peer);
        let start = Instant::now();
        let mut stream = loop {
            match UnixStream::connect(&path) {
                Ok(s) => break s,
                Err(e) => {
                    if start.elapsed() > CONNECT_DEADLINE {
                        return Err(e);
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
            }
        };
        stream.write_all(&rank.to_le_bytes())?;
        streams[peer as usize] = Some(stream);
    }

    if let Some(h) = acceptor {
        let accepted = h
            .join()
            .map_err(|_| std::io::Error::new(ErrorKind::Other, "acceptor thread panicked"))??;
        for (src, s) in accepted {
            if (src as usize) >= size || src <= rank || streams[src as usize].is_some() {
                return Err(std::io::Error::new(
                    ErrorKind::InvalidData,
                    format!("bogus hello from peer claiming rank {src}"),
                ));
            }
            streams[src as usize] = Some(s);
        }
    }
    Ok(streams)
}

/// One frame mid-write: header + payload, with resume offsets.
struct Pending {
    header: [u8; 8],
    hdr_sent: usize,
    frame: Frame,
    data_sent: usize,
}

impl Pending {
    fn new(tag: Tag, frame: Frame) -> Pending {
        let len = frame.len() as u32;
        let mut header = [0u8; 8];
        header[..4].copy_from_slice(&tag.to_le_bytes());
        header[4..].copy_from_slice(&len.to_le_bytes());
        Pending { header, hdr_sent: 0, frame, data_sent: 0 }
    }
}

/// Outbound state of one peer connection.
struct Peer {
    /// Nonblocking write half (the reader thread owns a blocking clone).
    stream: UnixStream,
    queue: VecDeque<Pending>,
    queued_bytes: usize,
    /// Set when a write failed hard: the peer is gone; frames to it drop.
    closed: bool,
}

/// The Unix-domain-socket backend. See the module docs for the protocol.
pub struct UdsTransport {
    rank: u32,
    size: usize,
    pool: FramePool,
    mailbox: Arc<MailboxCore>,
    /// Indexed by peer rank; `None` at `rank` (loopback never dials).
    peers: Vec<Option<Peer>>,
    readers: Vec<std::thread::JoinHandle<()>>,
    stats: TransportStats,
    shut: bool,
}

impl UdsTransport {
    /// Bind, dial and join the full mesh for `rank` of `size` under
    /// `dir`. Blocks until every pairwise connection is up (bounded by
    /// [`CONNECT_DEADLINE`] per peer), so a returned transport is fully
    /// connected — no send can race an unestablished stream.
    pub fn connect(dir: &Path, rank: u32, size: usize) -> std::io::Result<UdsTransport> {
        assert!((rank as usize) < size);
        let pool = FramePool::new();
        let mailbox = Arc::new(MailboxCore::new(size));
        let streams = connect_mesh(dir, rank, size)?;

        let mut peers: Vec<Option<Peer>> = (0..size).map(|_| None).collect();
        let mut readers = Vec::with_capacity(size.saturating_sub(1));
        for (src, slot) in streams.into_iter().enumerate() {
            let Some(stream) = slot else { continue };
            let read_half = stream.try_clone()?;
            readers.push(spawn_reader(
                src as u32,
                read_half,
                pool.clone(),
                Arc::clone(&mailbox),
            ));
            stream.set_nonblocking(true)?;
            peers[src] =
                Some(Peer { stream, queue: VecDeque::new(), queued_bytes: 0, closed: false });
        }

        Ok(UdsTransport {
            rank,
            size,
            pool,
            mailbox,
            peers,
            readers,
            stats: TransportStats::default(),
            shut: false,
        })
    }

    /// Flush one peer's queue as far as the socket accepts right now.
    /// Returns frames fully written. A hard write error closes the peer
    /// and drops its queue (counted).
    fn flush_peer(peer: &mut Peer, stats: &mut TransportStats) -> usize {
        if peer.closed {
            return 0;
        }
        let mut completed = 0;
        while let Some(p) = peer.queue.front_mut() {
            while p.hdr_sent < 8 {
                match peer.stream.write(&p.header[p.hdr_sent..]) {
                    Ok(0) => {
                        Self::close_peer(peer, stats);
                        return completed;
                    }
                    Ok(n) => p.hdr_sent += n,
                    Err(e) if e.kind() == ErrorKind::WouldBlock => return completed,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => {
                        Self::close_peer(peer, stats);
                        return completed;
                    }
                }
            }
            let data = p.frame.as_slice();
            while p.data_sent < data.len() {
                match peer.stream.write(&data[p.data_sent..]) {
                    Ok(0) => {
                        Self::close_peer(peer, stats);
                        return completed;
                    }
                    Ok(n) => p.data_sent += n,
                    Err(e) if e.kind() == ErrorKind::WouldBlock => return completed,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => {
                        Self::close_peer(peer, stats);
                        return completed;
                    }
                }
            }
            let done = peer.queue.pop_front().expect("front_mut() just yielded this entry");
            peer.queued_bytes -= done.frame.len();
            completed += 1; // Frame drops here: its buffer recycles.
        }
        completed
    }

    fn close_peer(peer: &mut Peer, stats: &mut TransportStats) {
        peer.closed = true;
        stats.frames_dropped_peer_closed += peer.queue.len() as u64;
        peer.queued_bytes = 0;
        peer.queue.clear();
    }

    fn window_full(&self) -> bool {
        let (mut frames, mut bytes) = (0usize, 0usize);
        for p in self.peers.iter().flatten() {
            frames += p.queue.len();
            bytes += p.queued_bytes;
        }
        frames > WINDOW_FRAMES || bytes > WINDOW_BYTES
    }
}

fn spawn_reader(
    src: u32,
    mut stream: UnixStream,
    pool: FramePool,
    mailbox: Arc<MailboxCore>,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("uds-rx-{src}"))
        .spawn(move || {
            let mut header = [0u8; 8];
            loop {
                if stream.read_exact(&mut header).is_err() {
                    return; // EOF or shutdown: the stream is done.
                }
                let tag = u32::from_le_bytes(header[..4].try_into().expect("4-byte slice"));
                let len =
                    u32::from_le_bytes(header[4..].try_into().expect("4-byte slice")) as usize;
                if len > MAX_FRAME_BYTES {
                    return; // Corrupt stream: abandon rather than OOM.
                }
                let mut buf = pool.take_vec();
                buf.resize(len, 0);
                if stream.read_exact(&mut buf).is_err() {
                    pool.recycle_vec(buf);
                    return;
                }
                mailbox.push(src, tag, pool.seal(buf));
            }
        })
        .expect("spawning a reader thread")
}

impl Transport for UdsTransport {
    fn kind(&self) -> TransportKind {
        TransportKind::Uds
    }

    fn rank(&self) -> u32 {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    fn frame_pool(&self) -> &FramePool {
        &self.pool
    }

    fn mailbox(&self) -> &Arc<MailboxCore> {
        &self.mailbox
    }

    fn send(&mut self, dst: u32, tag: Tag, frame: Frame) {
        assert!((dst as usize) < self.size);
        if dst == self.rank {
            self.mailbox.push(self.rank, tag, frame);
            return;
        }
        self.stats.frames_sent += 1;
        self.stats.bytes_sent += frame.len() as u64;
        {
            let peer = self.peers[dst as usize]
                .as_mut()
                .expect("connect() established every non-self peer");
            if peer.closed {
                self.stats.frames_dropped_peer_closed += 1;
                return;
            }
            peer.queued_bytes += frame.len();
            peer.queue.push_back(Pending::new(tag, frame));
            Self::flush_peer(peer, &mut self.stats);
        }
        // Backpressure: over the completion window, keep pumping (briefly
        // sleeping) until it drains — bounded by STALL_DEADLINE so a send
        // can never block indefinitely.
        if self.window_full() {
            let start = Instant::now();
            while self.window_full() && start.elapsed() < STALL_DEADLINE {
                self.stats.send_stalls += 1;
                std::thread::sleep(STALL_SLEEP);
                self.pump();
            }
        }
    }

    fn pump(&mut self) -> usize {
        let mut completed = 0;
        for peer in self.peers.iter_mut().flatten() {
            completed += Self::flush_peer(peer, &mut self.stats);
        }
        completed
    }

    fn inflight(&self) -> usize {
        self.peers.iter().flatten().map(|p| p.queue.len()).sum()
    }

    fn poll_interval(&self) -> Option<Duration> {
        // Blocked receives wake this often to pump. Tighter while writes
        // are pending (the bounded completion-latency contract), relaxed
        // when idle.
        if self.inflight() > 0 {
            Some(Duration::from_millis(1))
        } else {
            Some(Duration::from_millis(5))
        }
    }

    fn stats(&self) -> TransportStats {
        self.stats
    }

    fn shutdown(&mut self) {
        if self.shut {
            return;
        }
        self.shut = true;
        // Best-effort flush of everything still queued.
        let deadline = Instant::now() + Duration::from_secs(2);
        while self.inflight() > 0 && Instant::now() < deadline {
            if self.pump() == 0 {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        // Closing the sockets unblocks our reader threads (clones share
        // the underlying socket), so the joins below are bounded.
        for peer in self.peers.iter_mut().flatten() {
            let _ = peer.stream.shutdown(std::net::Shutdown::Both);
        }
        for h in self.readers.drain(..) {
            let _ = h.join();
        }
        self.mailbox.close();
    }
}

impl Drop for UdsTransport {
    fn drop(&mut self) {
        self.shutdown();
    }
}
