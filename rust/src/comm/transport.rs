//! The pluggable transport seam under [`Communicator`](super::Communicator).
//!
//! Everything above this line — chaos injection, CRC/seq framing, the
//! NACK retry archive, delta resync, the liveness plane, the collectives
//! protocol — lives in `Communicator` and is **backend-independent**. A
//! [`Transport`] only answers two questions: *how do published frames
//! reach the destination rank's mailbox* and *where do my own arrivals
//! land*. Three implementations ship:
//!
//! * [`InProcTransport`](super::mpi::InProcTransport) — the simulated MPI
//!   of PRs 1–7: ranks are threads, a send is a mailbox push, delivery is
//!   a pointer move (zero-copy, the modeled RDMA segment).
//! * [`UdsTransport`](super::uds::UdsTransport) — real OS processes over
//!   Unix-domain sockets, true nonblocking sends with a bounded
//!   completion window and per-peer reader threads.
//! * [`ShmTransport`](super::shm::ShmTransport) — real OS processes over
//!   a per-rank shared-memory slab file (tmpfs): payload bytes travel
//!   through the slab, only tiny descriptors cross the socket, and slab
//!   slots recycle on explicit release records (the `FramePool`
//!   publish/recycle discipline mapped onto shared memory).
//!
//! # The mailbox: per-source queues with a round-robin cursor
//!
//! Every backend delivers into the same [`MailboxCore`]: one FIFO queue
//! per source rank plus a rotating ANY-source cursor. Matching a
//! specific source scans only that source's queue (per-channel FIFO is
//! preserved exactly); matching ANY source starts at the cursor and
//! advances it past each hit, so a source that floods the mailbox can
//! delay a quiet source's frame by at most one full rotation — the
//! "recv_any fairness" contract the conformance suite asserts. The old
//! single-queue mailbox served ANY-receives in strict global arrival
//! order, which let one fast peer starve the rest indefinitely.

use super::mpi::{Frame, FramePool, RecvMsg, Tag};
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// Which backend a [`Transport`] is (config/CLI facing).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// Thread-per-rank simulated MPI (single process).
    InProcess,
    /// One OS process per rank over Unix-domain sockets.
    Uds,
    /// One OS process per rank over a shared-memory slab + UDS control.
    Shm,
}

impl TransportKind {
    pub fn parse(s: &str) -> Option<TransportKind> {
        match s {
            "inprocess" | "in-process" | "threads" => Some(TransportKind::InProcess),
            "uds" | "socket" => Some(TransportKind::Uds),
            "shm" | "shared-memory" => Some(TransportKind::Shm),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TransportKind::InProcess => "inprocess",
            TransportKind::Uds => "uds",
            TransportKind::Shm => "shm",
        }
    }

    /// Whether this backend runs each rank in its own OS process.
    pub fn multiprocess(self) -> bool {
        !matches!(self, TransportKind::InProcess)
    }
}

/// Lifetime counters of one transport endpoint (all backends; fields a
/// backend has no concept of stay zero).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TransportStats {
    /// Frames handed to [`Transport::send`] (loopback excluded).
    pub frames_sent: u64,
    /// Payload bytes handed to [`Transport::send`] (loopback excluded).
    pub bytes_sent: u64,
    /// Times a send blocked briefly because the bounded completion
    /// window was full (backpressure events, not an error).
    pub send_stalls: u64,
    /// Frames dropped because the peer's connection closed (a dead rank;
    /// the liveness plane handles the consequences).
    pub frames_dropped_peer_closed: u64,
    /// Shm only: payloads that travelled inline over the control socket
    /// because the slab had no free extent (counted fallback, never an
    /// error).
    pub inline_fallbacks: u64,
    /// Shm only: slab extents released back by receivers.
    pub slab_releases: u64,
}

/// One rank's inbound mailbox: per-source FIFO queues plus the rotating
/// ANY-source cursor. Shared (`Arc`) between the owning [`Transport`] /
/// [`Communicator`](super::Communicator) and any backend reader threads.
#[derive(Debug)]
pub struct MailboxCore {
    state: Mutex<MailboxState>,
    cv: Condvar,
}

#[derive(Debug)]
struct MailboxState {
    /// `per_src[s]` holds frames from rank `s` in arrival order.
    per_src: Vec<VecDeque<(Tag, Frame)>>,
    /// Total queued messages (all sources).
    queued: usize,
    /// Next source the ANY-source scan starts from.
    cursor: usize,
    /// Set by [`MailboxCore::close`]: blocking receives stop sleeping.
    closed: bool,
}

impl MailboxCore {
    pub fn new(sources: usize) -> MailboxCore {
        MailboxCore {
            state: Mutex::new(MailboxState {
                per_src: (0..sources).map(|_| VecDeque::new()).collect(),
                queued: 0,
                cursor: 0,
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Deliver a frame from `src` (any thread).
    pub fn push(&self, src: u32, tag: Tag, data: Frame) {
        let mut st = self.state.lock().expect("poisoned mailbox lock");
        st.per_src[src as usize].push_back((tag, data));
        st.queued += 1;
        self.cv.notify_all();
    }

    /// Non-blocking matched take (src/tag `None` = ANY). ANY-source
    /// matching rotates the fairness cursor; specific-source matching
    /// takes the first tag match of that source's FIFO.
    pub fn try_take(&self, src: Option<u32>, tag: Option<Tag>) -> Option<RecvMsg> {
        let mut st = self.state.lock().expect("poisoned mailbox lock");
        Self::take_locked(&mut st, src, tag)
    }

    /// Matched take; if nothing matches, wait for a push (or `max_wait`
    /// when given) and try once more. Callers loop — the two-phase shape
    /// lets them run work (e.g. [`Transport::pump`]) between sleeps
    /// without holding the lock.
    pub fn take_or_wait(
        &self,
        src: Option<u32>,
        tag: Option<Tag>,
        max_wait: Option<Duration>,
    ) -> Option<RecvMsg> {
        let mut st = self.state.lock().expect("poisoned mailbox lock");
        if let Some(m) = Self::take_locked(&mut st, src, tag) {
            return Some(m);
        }
        if st.closed {
            return None;
        }
        let mut st = match max_wait {
            Some(d) => self.cv.wait_timeout(st, d).expect("poisoned mailbox lock").0,
            None => self.cv.wait(st).expect("poisoned mailbox lock"),
        };
        Self::take_locked(&mut st, src, tag)
    }

    fn take_locked(st: &mut MailboxState, src: Option<u32>, tag: Option<Tag>) -> Option<RecvMsg> {
        if st.queued == 0 {
            return None;
        }
        let n = st.per_src.len();
        match src {
            Some(s) => {
                let q = &mut st.per_src[s as usize];
                let idx = q.iter().position(|(t, _)| tag.map_or(true, |want| *t == want))?;
                let (t, data) = q.remove(idx).expect("position() yields an in-range index");
                st.queued -= 1;
                Some(RecvMsg { src: s, tag: t, data })
            }
            None => {
                for step in 0..n {
                    let s = (st.cursor + step) % n;
                    let q = &mut st.per_src[s];
                    if let Some(idx) =
                        q.iter().position(|(t, _)| tag.map_or(true, |want| *t == want))
                    {
                        let (t, data) =
                            q.remove(idx).expect("position() yields an in-range index");
                        st.queued -= 1;
                        // Advance past the source we just served so the
                        // next ANY-receive starts at its successor.
                        st.cursor = (s + 1) % n;
                        return Some(RecvMsg { src: s as u32, tag: t, data });
                    }
                }
                None
            }
        }
    }

    /// Probe without removal (does not move the fairness cursor).
    pub fn peek(&self, src: Option<u32>, tag: Option<Tag>) -> Option<(u32, Tag, usize)> {
        let st = self.state.lock().expect("poisoned mailbox lock");
        for (s, q) in st.per_src.iter().enumerate() {
            if src.is_some_and(|want| want as usize != s) {
                continue;
            }
            if let Some((t, f)) = q.iter().find(|(t, _)| tag.map_or(true, |want| *t == want)) {
                return Some((s as u32, *t, f.len()));
            }
        }
        None
    }

    /// Whether anything (any tag) is queued from `src` — the liveness
    /// plane's "queued message proves the peer alive" probe.
    pub fn has_from(&self, src: u32) -> bool {
        let st = self.state.lock().expect("poisoned mailbox lock");
        !st.per_src[src as usize].is_empty()
    }

    /// Drop every queued message with `tag`; returns how many.
    pub fn cancel(&self, tag: Tag) -> usize {
        let mut st = self.state.lock().expect("poisoned mailbox lock");
        let mut dropped = 0;
        for q in st.per_src.iter_mut() {
            let before = q.len();
            q.retain(|(t, _)| *t != tag);
            dropped += before - q.len();
        }
        st.queued -= dropped;
        dropped
    }

    /// Total queued messages.
    pub fn len(&self) -> usize {
        self.state.lock().expect("poisoned mailbox lock").queued
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Mark the mailbox closed (shutdown): blocked receivers wake and
    /// stop sleeping on the condvar. Queued messages remain takeable.
    pub fn close(&self) {
        let mut st = self.state.lock().expect("poisoned mailbox lock");
        st.closed = true;
        self.cv.notify_all();
    }

    /// Whether [`MailboxCore::close`] has been called. Receive loops use
    /// this to turn "blocked on a mailbox that will never fill" into a
    /// typed timeout instead of a hot spin.
    pub fn is_closed(&self) -> bool {
        self.state.lock().expect("poisoned mailbox lock").closed
    }
}

/// The backend contract. Object-safe and `Send` — a
/// [`Communicator`](super::Communicator) owns one as `Box<dyn Transport>`
/// and moves with it into its rank thread/process.
///
/// Invariants every backend must provide (the conformance suite in
/// `rust/tests/transport_conformance.rs` asserts them over all
/// implementations):
///
/// * **Per-channel FIFO**: frames sent on one `(src, dst, tag)` channel
///   are delivered into `dst`'s mailbox in send order.
/// * **Integrity**: delivered bytes equal sent bytes (corruption may only
///   come from the chaos seam *above* the transport).
/// * **Loopback**: `send(self_rank, ..)` delivers into the own mailbox
///   without touching the wire.
/// * **Bounded completion**: after a send is accepted, a bounded number
///   of [`Transport::pump`] calls (or subsequent sends) completes its
///   write and releases the frame back to its pool — no completion may
///   depend on unbounded future traffic.
pub trait Transport: Send {
    fn kind(&self) -> TransportKind;
    fn rank(&self) -> u32;
    fn size(&self) -> usize;

    /// The pool send-side leases publish buffers from. In-process this is
    /// the world-shared pool (receiver drops recycle to the sender);
    /// multiprocess backends have one pool per process.
    fn frame_pool(&self) -> &FramePool;

    /// This endpoint's inbound mailbox (all arrivals land here).
    fn mailbox(&self) -> &std::sync::Arc<MailboxCore>;

    /// Move `frame` to `dst`'s mailbox. Accepts `dst == rank()`
    /// (loopback: a plain local push). Never blocks indefinitely: a full
    /// completion window may stall briefly (counted in
    /// [`TransportStats::send_stalls`]); a closed peer drops the frame
    /// (counted in [`TransportStats::frames_dropped_peer_closed`]).
    fn send(&mut self, dst: u32, tag: Tag, frame: Frame);

    /// Drive pending nonblocking work (flush queued writes, harvest
    /// completion/release records). Returns the number of sends completed
    /// by this call. In-process: no-op returning 0.
    fn pump(&mut self) -> usize;

    /// Sends accepted but not yet fully written to the wire.
    fn inflight(&self) -> usize;

    /// How often a blocked receive should wake to [`Transport::pump`].
    /// `None` = never (pure condvar waits; the in-process backend has no
    /// pending work by construction).
    fn poll_interval(&self) -> Option<Duration> {
        None
    }

    fn stats(&self) -> TransportStats {
        TransportStats::default()
    }

    /// Backend-native allgather, if the backend has one (the in-process
    /// condvar rendezvous). `None` ⇒ the communicator runs its p2p
    /// gather+broadcast fallback over plain sends.
    fn native_allgather(&mut self, _data: &[u8]) -> Option<Vec<Vec<u8>>> {
        None
    }

    /// Backend-native barrier; `false` ⇒ the communicator synthesizes a
    /// barrier from an empty allgather.
    fn native_barrier(&mut self) -> bool {
        false
    }

    /// Flush best-effort and release OS resources. Idempotent; called on
    /// communicator drop.
    fn shutdown(&mut self);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(b: &[u8]) -> Frame {
        Frame::owned(b.to_vec())
    }

    #[test]
    fn specific_source_take_preserves_fifo_and_tag_selectivity() {
        let mb = MailboxCore::new(2);
        mb.push(1, 5, frame(b"a"));
        mb.push(1, 9, frame(b"b"));
        mb.push(1, 5, frame(b"c"));
        // Tag-selective take skips the non-matching head.
        let m = mb.try_take(Some(1), Some(9)).unwrap();
        assert_eq!(&m.data[..], b"b");
        // Remaining tag-5 messages still come in FIFO order.
        assert_eq!(&mb.try_take(Some(1), Some(5)).unwrap().data[..], b"a");
        assert_eq!(&mb.try_take(Some(1), Some(5)).unwrap().data[..], b"c");
        assert!(mb.try_take(Some(1), None).is_none());
        assert!(mb.is_empty());
    }

    #[test]
    fn any_source_take_round_robins_across_sources() {
        let mb = MailboxCore::new(3);
        // Source 1 floods; source 2 contributes one message.
        for i in 0..10u8 {
            mb.push(1, 7, frame(&[i]));
        }
        mb.push(2, 7, frame(b"quiet"));
        // First ANY-take serves source 1 (cursor at 0 → first nonempty).
        assert_eq!(mb.try_take(None, Some(7)).unwrap().src, 1);
        // The cursor now sits past source 1, so the quiet source is next
        // despite the 9 flooded messages still queued ahead of it in
        // arrival order.
        let m = mb.try_take(None, Some(7)).unwrap();
        assert_eq!(m.src, 2);
        assert_eq!(&m.data[..], b"quiet");
        // Then the rotation wraps back to the flooder.
        assert_eq!(mb.try_take(None, Some(7)).unwrap().src, 1);
    }

    #[test]
    fn peek_reports_without_consuming_or_rotating() {
        let mb = MailboxCore::new(2);
        mb.push(0, 3, frame(b"xyz"));
        assert_eq!(mb.peek(None, None), Some((0, 3, 3)));
        assert_eq!(mb.peek(Some(1), None), None);
        assert_eq!(mb.peek(None, Some(4)), None);
        assert_eq!(mb.len(), 1);
    }

    #[test]
    fn cancel_drops_only_the_given_tag() {
        let mb = MailboxCore::new(2);
        mb.push(0, 1, frame(b"a"));
        mb.push(1, 1, frame(b"b"));
        mb.push(1, 2, frame(b"c"));
        assert_eq!(mb.cancel(1), 2);
        assert_eq!(mb.len(), 1);
        assert_eq!(mb.try_take(None, None).unwrap().tag, 2);
    }

    #[test]
    fn has_from_sees_any_tag() {
        let mb = MailboxCore::new(2);
        assert!(!mb.has_from(1));
        mb.push(1, 99, frame(b""));
        assert!(mb.has_from(1));
        assert!(!mb.has_from(0));
    }

    #[test]
    fn take_or_wait_honors_timeout_and_close() {
        use std::time::Instant;
        let mb = MailboxCore::new(1);
        let t0 = Instant::now();
        assert!(mb.take_or_wait(None, None, Some(Duration::from_millis(20))).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(15));
        // Closed mailboxes stop sleeping but still drain their queue.
        mb.push(0, 1, frame(b"last"));
        mb.close();
        assert_eq!(&mb.take_or_wait(None, None, None).unwrap().data[..], b"last");
        let t1 = Instant::now();
        assert!(mb.take_or_wait(None, None, None).is_none());
        assert!(t1.elapsed() < Duration::from_millis(50), "closed mailbox must not block");
    }

    #[test]
    fn kind_parse_round_trips() {
        for k in [TransportKind::InProcess, TransportKind::Uds, TransportKind::Shm] {
            assert_eq!(TransportKind::parse(k.name()), Some(k));
        }
        assert!(TransportKind::parse("smoke-signals").is_none());
        assert!(!TransportKind::InProcess.multiprocess());
        assert!(TransportKind::Uds.multiprocess());
        assert!(TransportKind::Shm.multiprocess());
    }
}
