//! Cross-rank communication (§2.4.3).
//!
//! The paper runs on MPI; this environment has no MPI or cluster, so
//! [`mpi`] provides an in-process *simulated MPI*: each rank is an OS
//! thread, ranks share nothing except the transport, and every cross-rank
//! byte goes through explicit serialized messages — keeping the
//! serialization/compression costs the paper measures fully real. The
//! [`network`] model charges simulated wire time per message so that
//! interconnect-sensitivity experiments (InfiniBand vs Gigabit Ethernet,
//! Fig. 11) are reproducible. [`batching`] splits large messages into
//! bounded chunks (§2.4.3's transmission-buffer memory cap).
//!
//! Message framing: every engine transfer is `(peer, tag)`-addressed
//! ([`mpi::tags`] — aura, migration, control), chunked by
//! [`batching::send_batched`] on the way out and reassembled into a
//! caller-reused buffer by [`batching::Reassembler`] on the way in.
//! All-to-all rounds carry a monotone round counter so barrier-free
//! ranks pair up the same logical exchange even when they drift apart.
//! Transport buffers are owned `Vec`s in the in-process mailboxes — see
//! ROADMAP "shared-memory transport frames" for the planned zero-copy
//! wire.

pub mod batching;
pub mod mpi;
pub mod network;

pub use mpi::{Communicator, MpiWorld, RecvMsg, Tag};
pub use network::NetworkModel;
