//! Cross-rank communication (§2.4.3).
//!
//! The paper runs on MPI; this environment has no MPI or cluster, so
//! [`mpi`] provides an in-process *simulated MPI*: each rank is an OS
//! thread, ranks share nothing except the transport, and every cross-rank
//! byte goes through explicit serialized messages — keeping the
//! serialization/compression costs the paper measures fully real. The
//! [`network`] model charges simulated wire time per message so that
//! interconnect-sensitivity experiments (InfiniBand vs Gigabit Ethernet,
//! Fig. 11) are reproducible. [`batching`] splits large messages into
//! bounded chunks (§2.4.3's transmission-buffer memory cap).
//!
//! Message framing: every engine transfer is `(peer, tag)`-addressed
//! ([`mpi::tags`] — aura, migration, control), chunked by
//! [`batching::send_batched`] / [`batching::send_batched_framed`] on the
//! way out and reassembled by [`batching::Reassembler`] on the way in.
//! All-to-all rounds carry a monotone round counter so barrier-free
//! ranks pair up the same logical exchange even when they drift apart.
//!
//! Transport buffers are refcounted pooled [`mpi::Frame`]s drawn from the
//! world's shared [`mpi::FramePool`] — the in-process model of an
//! RDMA-style shared-memory wire. A message that fits one chunk travels
//! **zero-copy**: the encoder writes its wire into a pool-leased buffer
//! (after a reserved [`batching::FRAME_HEADER`] gap), the framed send
//! publishes that very buffer to the receiver's mailbox, the receiver
//! borrows it in place ([`batching::WireSlot::Direct`]) and decodes
//! straight out of it; dropping the last reference recycles the buffer
//! for the next sender. Multi-chunk messages stage each chunk into a
//! pooled frame and reassemble once into a buffer shared with the decode
//! pool — still allocation-free, with the copied bytes metered
//! (`RecvAllStats::copied_bytes`). The wire format itself and the full
//! frame lifecycle are documented in `ARCHITECTURE.md` §"Transport and
//! frame lifecycle".

//! Fault tolerance: frames carry a CRC32 + per-link sequence number in
//! their header; [`chaos`] injects deterministic faults (drop, delay,
//! duplicate, reorder, truncate, bit-flip) at the send seam, and the
//! reliable receive path recovers via NACK-driven retransmission from
//! refcounted frame archives — see `ARCHITECTURE.md` §"Fault tolerance".
//!
//! # Transport backends
//!
//! Everything above runs against the pluggable [`transport::Transport`]
//! seam. Three backends ship: the in-process thread-per-rank mailboxes
//! ([`mpi::InProcTransport`]), real OS processes over Unix-domain
//! sockets ([`uds::UdsTransport`]), and real OS processes over a
//! shared-memory slab + UDS control stream ([`shm::ShmTransport`]). The
//! protocol layers (chaos, retries, liveness, collectives) are
//! backend-independent; `rust/tests/transport_conformance.rs` asserts
//! the shared contract over all three. See `ARCHITECTURE.md`
//! §"Transport backends".

pub mod batching;
pub mod chaos;
pub mod mpi;
pub mod network;
pub mod shm;
pub mod transport;
pub mod uds;

pub use chaos::{ChaosStats, FaultPlan};
pub use mpi::{
    CommError, Communicator, Frame, FrameBuf, FramePool, FramePoolStats, MpiWorld, RecvMsg, Tag,
};
pub use network::NetworkModel;
pub use shm::ShmTransport;
pub use transport::{MailboxCore, Transport, TransportKind, TransportStats};
pub use uds::UdsTransport;
