//! Interconnect cost model.
//!
//! The paper evaluates on two fabrics: Snellius InfiniBand (200 Gb/s
//! in-rack / 100 Gb/s across racks, microsecond latency) and System B's
//! Gigabit Ethernet. Delta encoding pays off on the slow fabric and not on
//! the fast one (§3.11) — a pure bytes×(latency, bandwidth) effect, which
//! this model reproduces: each message is charged
//! `latency + bytes / bandwidth` seconds of *simulated* network time,
//! accumulated per rank and reported next to wall time. The charge
//! applies per transport frame — chunked and framed sends alike — so
//! compression and delta savings show up as simulated seconds exactly as
//! they would on the real fabric:
//!
//! ```
//! use teraagent::comm::NetworkModel;
//! let gige = NetworkModel::gige();
//! // 1 MiB over 1 Gb/s: ~8.4 ms of wire time + 50 µs latency.
//! let secs = gige.transfer_secs(1 << 20);
//! assert!(secs > 8.0e-3 && secs < 9.0e-3);
//! assert_eq!(NetworkModel::ideal().transfer_secs(1 << 30), 0.0);
//! ```

/// Latency/bandwidth model of one link class.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NetworkModel {
    /// One-way message latency in seconds.
    pub latency_s: f64,
    /// Bandwidth in bytes/second.
    pub bandwidth_bps: f64,
    pub name: &'static str,
}

impl NetworkModel {
    /// Ideal fabric: zero cost (pure wall-clock runs).
    pub fn ideal() -> Self {
        NetworkModel { latency_s: 0.0, bandwidth_bps: f64::INFINITY, name: "ideal" }
    }

    /// InfiniBand HDR-class fabric (Snellius genoa partition: 200 Gb/s
    /// within a rack; we use the conservative cross-rack 100 Gb/s).
    pub fn infiniband() -> Self {
        NetworkModel { latency_s: 2e-6, bandwidth_bps: 100e9 / 8.0, name: "infiniband" }
    }

    /// Gigabit Ethernet (System B): ~50 µs latency, 1 Gb/s.
    pub fn gige() -> Self {
        NetworkModel { latency_s: 50e-6, bandwidth_bps: 1e9 / 8.0, name: "gige" }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "ideal" => Some(Self::ideal()),
            "infiniband" | "ib" => Some(Self::infiniband()),
            "gige" | "ethernet" => Some(Self::gige()),
            _ => None,
        }
    }

    /// Simulated seconds to transfer one message of `bytes`.
    #[inline]
    pub fn transfer_secs(&self, bytes: usize) -> f64 {
        if self.bandwidth_bps.is_infinite() {
            return self.latency_s;
        }
        self.latency_s + bytes as f64 / self.bandwidth_bps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_is_free() {
        let m = NetworkModel::ideal();
        assert_eq!(m.transfer_secs(1 << 30), 0.0);
    }

    #[test]
    fn gige_slower_than_infiniband() {
        let bytes = 10 * 1024 * 1024;
        let ib = NetworkModel::infiniband().transfer_secs(bytes);
        let ge = NetworkModel::gige().transfer_secs(bytes);
        assert!(ge > 50.0 * ib, "gige {ge} vs ib {ib}");
    }

    #[test]
    fn latency_dominates_small_messages() {
        let m = NetworkModel::gige();
        let small = m.transfer_secs(64);
        assert!((small - m.latency_s) / m.latency_s < 0.02);
    }

    #[test]
    fn bandwidth_dominates_large_messages() {
        let m = NetworkModel::gige();
        let t = m.transfer_secs(125_000_000); // 1 Gb -> ~1 s
        assert!((t - 1.0).abs() < 0.01, "t={t}");
    }

    #[test]
    fn parse_presets() {
        assert_eq!(NetworkModel::parse("ib").unwrap().name, "infiniband");
        assert_eq!(NetworkModel::parse("gige").unwrap().name, "gige");
        assert!(NetworkModel::parse("x").is_none());
    }
}
