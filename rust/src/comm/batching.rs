//! Large-message batching (§2.4.3: "we transmit large messages in smaller
//! batches to reduce the memory needed for transmission buffers,
//! compression, and serialization") over the pooled-frame transport.
//!
//! A payload larger than the configured chunk size is split into numbered
//! chunks; the receiver reassembles them. Framing: `[msg_id u32]
//! [chunk u32][total u32][seq u32][crc u32][bytes...]`, all little-endian.
//! `seq` is a per-`(link, tag)` monotone counter (gap/reorder detection —
//! observational, never rejecting); `crc` is a CRC32 over every frame
//! byte *except* the crc field itself, so header and body corruption are
//! both caught on receive ([`FrameError`]). Verified faults feed the
//! [`Reassembler::faults`] counters and, on the reliable receive path
//! ([`recv_all_batched_reliable`]), trigger NACK-driven retransmission
//! from the sender's frame archive.
//!
//! # Copy discipline
//!
//! The send side has two entry points. [`send_batched`] borrows the wire
//! (`&[u8]`) and stages header + chunk into pooled frames — one copy per
//! chunk, no allocation. [`send_batched_framed`] is the zero-copy fast
//! path the aura exchange uses: the caller encodes the wire into its
//! buffer **after a reserved [`FRAME_HEADER`]-byte gap**, the header is
//! written into the gap in place, and the whole buffer is published as a
//! pooled [`Frame`] — the bytes the encoder wrote are the bytes the
//! decoder reads, with the pool lending the caller a recycled replacement
//! buffer for the next iteration.
//!
//! The receive side mirrors this with [`WireSlot`]: a message that fit a
//! single frame is handed over as [`WireSlot::Direct`] — the frame
//! itself, body borrowed in place, **zero receive-side copies** — while a
//! multi-chunk message is staged once into a pooled aligned buffer shared
//! with the decode [`ViewPool`] ([`WireSlot::Staged`]; the per-frame
//! copy is metered in [`RecvAllStats::copied_bytes`]). Either way the
//! steady state allocates nothing.

#![cfg_attr(not(test), deny(clippy::unwrap_used))]

use super::mpi::{CommError, Communicator, Frame, Tag};
use crate::io::buffer::AlignedBuf;
use crate::io::codec::WirePayload;
use crate::io::ta_io::ViewPool;
use crate::util::crc32::Crc32;
use crate::util::timing::CpuTimer;
use std::collections::HashMap;
use std::time::Duration;

/// Default chunk size (1 MiB) — bounds peak transmission-buffer memory.
pub const DEFAULT_CHUNK_BYTES: usize = 1 << 20;

/// Bytes of the per-chunk framing header (`msg_id`, `chunk`, `total`,
/// `seq`, `crc`). [`send_batched_framed`] callers reserve this many bytes
/// at the front of their wire buffer so single-chunk messages publish
/// without a copy.
pub const FRAME_HEADER: usize = 20;

/// Byte offset of the CRC field — the only header bytes excluded from
/// the checksum (a CRC cannot cover itself).
const CRC_OFFSET: usize = 16;

/// Why a received frame was rejected. Every variant is recoverable: the
/// frame is dropped, the fault is counted, and on the reliable path the
/// message is NACKed for retransmission.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameError {
    /// Shorter than the framing header — truncated in flight.
    Short { len: usize },
    /// Checksum mismatch — corrupted (bit-flip or body truncation).
    BadCrc { expected: u32, actual: u32 },
    /// `chunk >= total` — a header that cannot describe a real stream.
    ChunkOutOfRange { chunk: u32, total: u32 },
    /// A chunk whose `total` disagrees with earlier chunks of the same
    /// message — stale or corrupted stream state.
    InconsistentTotal { expected: u32, got: u32 },
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Short { len } => write!(f, "frame shorter than header ({len} bytes)"),
            FrameError::BadCrc { expected, actual } => {
                write!(f, "frame checksum mismatch (expected {expected:#010x}, got {actual:#010x})")
            }
            FrameError::ChunkOutOfRange { chunk, total } => {
                write!(f, "chunk index {chunk} out of range for total {total}")
            }
            FrameError::InconsistentTotal { expected, got } => {
                write!(f, "chunk total {got} disagrees with stream total {expected}")
            }
        }
    }
}

impl std::error::Error for FrameError {}

fn header(msg_id: u32, chunk: u32, total: u32, seq: u32) -> [u8; FRAME_HEADER] {
    let mut h = [0u8; FRAME_HEADER];
    h[0..4].copy_from_slice(&msg_id.to_le_bytes());
    h[4..8].copy_from_slice(&chunk.to_le_bytes());
    h[8..12].copy_from_slice(&total.to_le_bytes());
    h[12..16].copy_from_slice(&seq.to_le_bytes());
    // CRC field stamped separately once the body is known.
    h
}

/// CRC over every frame byte except the CRC field itself, with the body
/// supplied separately (the send side streams header + body without
/// concatenating them first).
fn frame_crc(header: &[u8], body: &[u8]) -> u32 {
    Crc32::new().update(&header[..CRC_OFFSET]).update(body).finalize()
}

fn read_u32(frame: &[u8], at: usize) -> u32 {
    let b: [u8; 4] = frame[at..at + 4].try_into().expect("4-byte slice converts to [u8; 4]");
    u32::from_le_bytes(b)
}

/// Validate and parse a received frame header. Returns
/// `(msg_id, chunk, total, seq)` or the fault that condemns the frame.
fn verify_header(frame: &[u8]) -> Result<(u32, u32, u32, u32), FrameError> {
    if frame.len() < FRAME_HEADER {
        return Err(FrameError::Short { len: frame.len() });
    }
    let expected = read_u32(frame, CRC_OFFSET);
    let actual = frame_crc(&frame[..FRAME_HEADER], &frame[FRAME_HEADER..]);
    if actual != expected {
        return Err(FrameError::BadCrc { expected, actual });
    }
    let (msg_id, chunk, total, seq) =
        (read_u32(frame, 0), read_u32(frame, 4), read_u32(frame, 8), read_u32(frame, 12));
    if chunk >= total {
        return Err(FrameError::ChunkOutOfRange { chunk, total });
    }
    Ok((msg_id, chunk, total, seq))
}

/// Sender side: split `data` into frames and send them to `dst` on `tag`.
/// `msg_id` must be unique per (sender, receiver, tag) stream position —
/// the engine uses its iteration counter.
///
/// The caller keeps ownership of `data`; each frame is staged (header +
/// chunk slice) into a pooled transport frame — one copy per chunk, zero
/// allocation. When the caller can reserve a [`FRAME_HEADER`] gap in its
/// buffer, [`send_batched_framed`] skips even that copy for single-chunk
/// messages.
pub fn send_batched(
    comm: &mut Communicator,
    dst: u32,
    tag: Tag,
    msg_id: u32,
    data: &[u8],
    chunk_bytes: usize,
) -> usize {
    let chunk_bytes = chunk_bytes.max(1);
    let total = data.len().div_ceil(chunk_bytes).max(1) as u32;
    let mut keep = Vec::new();
    if data.is_empty() {
        // Zero-length messages still need one frame so the receiver can
        // match the stream position.
        let h = stamped_header(comm, dst, tag, msg_id, 0, 1, &[]);
        send_chunk(comm, dst, tag, &h, &[], &mut keep);
    } else {
        for (i, chunk) in data.chunks(chunk_bytes).enumerate() {
            let h = stamped_header(comm, dst, tag, msg_id, i as u32, total, chunk);
            send_chunk(comm, dst, tag, &h, chunk, &mut keep);
        }
    }
    comm.archive_frames(dst, tag, msg_id, keep);
    total as usize
}

/// Stamp one chunk header: draw the channel sequence number and compute
/// the frame CRC (header-except-crc ++ body), metering the checksum cost
/// into `comm.checksum_secs`.
fn stamped_header(
    comm: &mut Communicator,
    dst: u32,
    tag: Tag,
    msg_id: u32,
    chunk: u32,
    total: u32,
    body: &[u8],
) -> [u8; FRAME_HEADER] {
    let seq = comm.next_seq(dst, tag);
    let mut h = header(msg_id, chunk, total, seq);
    let t = CpuTimer::start();
    let crc = frame_crc(&h, body);
    h[CRC_OFFSET..CRC_OFFSET + 4].copy_from_slice(&crc.to_le_bytes());
    comm.checksum_secs += t.elapsed_secs();
    h
}

/// Publish one staged chunk. Clean path: scatter-gather into a pooled
/// frame inside the communicator ([`Communicator::isend_parts`]) —
/// nothing retained, the pool's one-circulating-buffer steady state is
/// untouched. Reliable path: stage the same bytes here so a refcount
/// clone of the published frame can be archived for retransmission.
fn send_chunk(
    comm: &mut Communicator,
    dst: u32,
    tag: Tag,
    h: &[u8; FRAME_HEADER],
    chunk: &[u8],
    keep: &mut Vec<Frame>,
) {
    if comm.reliable() {
        let pool = comm.frame_pool().clone();
        let mut fb = pool.take();
        fb.as_mut_vec().reserve(FRAME_HEADER + chunk.len());
        fb.extend_from_slice(h);
        fb.extend_from_slice(chunk);
        let frame = fb.seal();
        keep.push(frame.clone());
        comm.isend_frame(dst, tag, frame);
    } else {
        comm.isend_parts(dst, tag, &[h, chunk]);
    }
}

/// The zero-copy batched send: `wire` holds `[FRAME_HEADER reserved gap]
/// [message bytes]` (the gap is what [`Codec::encode_rm_overlapped`]
/// leaves when asked for one). If the message fits one chunk, the header
/// is written into the gap and the **whole buffer is published in place**
/// as a pooled frame — no copy anywhere between the encoder's write and
/// the decoder's read — while `wire` is swapped for a recycled buffer
/// from the world's frame pool, keeping the caller's capacity cycling.
/// Larger messages fall back to per-chunk staging like [`send_batched`]
/// (the chunk split is itself the §2.4.3 memory cap) and leave `wire`
/// with the caller. Returns the number of frames sent.
///
/// [`Codec::encode_rm_overlapped`]: crate::io::codec::Codec::encode_rm_overlapped
pub fn send_batched_framed(
    comm: &mut Communicator,
    dst: u32,
    tag: Tag,
    msg_id: u32,
    wire: &mut Vec<u8>,
    chunk_bytes: usize,
) -> usize {
    assert!(wire.len() >= FRAME_HEADER, "framed wire is missing its header gap");
    let chunk_bytes = chunk_bytes.max(1);
    let body_len = wire.len() - FRAME_HEADER;
    if body_len <= chunk_bytes {
        let seq = comm.next_seq(dst, tag);
        wire[..FRAME_HEADER].copy_from_slice(&header(msg_id, 0, 1, seq));
        let t = CpuTimer::start();
        let crc = frame_crc(&wire[..FRAME_HEADER], &wire[FRAME_HEADER..]);
        wire[CRC_OFFSET..CRC_OFFSET + 4].copy_from_slice(&crc.to_le_bytes());
        comm.checksum_secs += t.elapsed_secs();
        let pool = comm.frame_pool().clone();
        let buf = std::mem::replace(wire, pool.take_vec());
        let frame = pool.seal(buf);
        if comm.reliable() {
            comm.archive_frames(dst, tag, msg_id, vec![frame.clone()]);
        }
        comm.isend_frame(dst, tag, frame);
        return 1;
    }
    let total = body_len.div_ceil(chunk_bytes) as u32;
    let mut keep = Vec::new();
    for (i, chunk) in wire[FRAME_HEADER..].chunks(chunk_bytes).enumerate() {
        let h = stamped_header(comm, dst, tag, msg_id, i as u32, total, chunk);
        send_chunk(comm, dst, tag, &h, chunk, &mut keep);
    }
    comm.archive_frames(dst, tag, msg_id, keep);
    total as usize
}

/// One source's completed wire on the receive side: either the published
/// frame itself (single-chunk — the decode reads the sender's bytes in
/// place) or a pooled staging buffer the chunks were assembled into.
#[derive(Debug, Default)]
pub enum WireSlot {
    #[default]
    Empty,
    /// A complete single-frame message; the wire body follows the
    /// [`FRAME_HEADER`] in the frame the sender published.
    Direct(Frame),
    /// A multi-chunk message assembled into a buffer from the decode
    /// pool ([`ViewPool`]); recycle it back with
    /// [`WireSlot::recycle_into`].
    Staged(AlignedBuf),
}

impl WireSlot {
    /// The wire message bytes (codec envelope + payload).
    pub fn as_wire(&self) -> &[u8] {
        match self {
            WireSlot::Empty => &[],
            WireSlot::Direct(f) => &f[FRAME_HEADER..],
            WireSlot::Staged(b) => b.as_slice(),
        }
    }

    /// Release the backing storage: a staged buffer returns to `pool`, a
    /// direct frame recycles into its transport pool on drop.
    pub fn recycle_into(self, pool: &mut ViewPool) {
        if let WireSlot::Staged(buf) = self {
            pool.put_buf(buf);
        }
    }
}

impl AsRef<[u8]> for WireSlot {
    fn as_ref(&self) -> &[u8] {
        self.as_wire()
    }
}

impl WirePayload for WireSlot {
    fn wire(&self) -> &[u8] {
        self.as_wire()
    }

    fn recycle(self, pool: &mut ViewPool) {
        self.recycle_into(pool);
    }
}

/// Receiver-side reassembly state for interleaved chunked streams.
/// Chunks are held as received frames (frame-granular, no copy) until a
/// stream completes; only then is the payload assembled once into a
/// pooled buffer. All scratch recycles across messages.
#[derive(Debug, Default)]
pub struct Reassembler {
    /// (src, tag, msg_id) -> (received chunk frames, total)
    partial: HashMap<(u32, Tag, u32), (Vec<Option<Frame>>, u32)>,
    /// Freelist of chunk-slot vectors (capacity reused across streams).
    chunk_scratch: Vec<Vec<Option<Frame>>>,
    /// Per-source completion flags for [`recv_all_batched_streaming`]
    /// (capacity reused across iterations).
    done_scratch: Vec<bool>,
    /// Next expected sequence number per `(src, tag)` link.
    expected_seq: HashMap<(u32, Tag), u32>,
    /// Cumulative receive-side fault observations.
    pub faults: ReassemblyFaults,
    /// Thread-CPU seconds spent verifying frame checksums (the engine
    /// charges these to `Op::Checksum`).
    pub checksum_secs: f64,
}

/// Receive-side fault observations, cumulative over the reassembler's
/// lifetime. Sequence anomalies are *observational* — frames are never
/// rejected on sequence alone (a retransmitted frame legitimately
/// carries its original number); rejection happens only on integrity
/// failures ([`FrameError`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ReassemblyFaults {
    /// Frames dropped for checksum mismatch.
    pub crc_failures: u64,
    /// Frames dropped for being shorter than the header.
    pub short_frames: u64,
    /// Frames dropped for impossible or inconsistent chunk geometry.
    pub bad_geometry: u64,
    /// Sequence jumps forward (at least one frame lost or still in
    /// flight when its successor arrived).
    pub seq_gaps: u64,
    /// Frames that arrived with an already-passed sequence number
    /// (reordered, delayed, or retransmitted).
    pub out_of_order: u64,
    /// Duplicate chunks suppressed during reassembly.
    pub duplicates: u64,
}

impl ReassemblyFaults {
    /// Integrity faults that condemned a frame (excludes the
    /// observational sequence/duplicate counters).
    pub fn frames_rejected(&self) -> u64 {
        self.crc_failures + self.short_frames + self.bad_geometry
    }

    /// Every anomaly observed, rejected or not.
    pub fn detected(&self) -> u64 {
        self.frames_rejected() + self.seq_gaps + self.out_of_order + self.duplicates
    }
}

/// What one receive-all call spent where: wall-clock seconds blocked in
/// the transport (the honest wait), thread-CPU seconds spent parsing and
/// assembling frames, bytes copied by multi-chunk staging (`0` when every
/// message fit a single frame — the zero-copy fast path), and the number
/// of frames consumed. The engine charges `wait_secs` to `Op::Transfer`
/// and `reassembly_secs` to `Op::Reassembly`, and counts `copied_bytes`
/// under `Counter::BytesReassembled`.
#[derive(Clone, Copy, Debug, Default)]
pub struct RecvAllStats {
    pub wait_secs: f64,
    pub reassembly_secs: f64,
    pub copied_bytes: u64,
    pub frames: u64,
    /// Frames rejected by integrity checks during this call.
    pub faults_detected: u64,
    /// Retransmission requests (NACKs) sent during this call
    /// (reliable path only).
    pub retries_sent: u64,
    /// Completed messages discarded as stale or duplicate during this
    /// call (reliable path only).
    pub stale_dropped: u64,
}

/// Collect one complete batched message from **each** of `srcs` on `tag`,
/// consuming frames in *arrival* order — no fixed-rank-order blocking
/// wait: a slow first neighbor never stalls ingestion of everyone else's
/// already-arrived frames. The moment source `srcs[k]`'s message
/// completes, `complete(k, slot)` runs **on the calling thread** with the
/// finished [`WireSlot`] — this is the producer half of the streaming
/// ingest: feed the slot to decode workers
/// ([`Codec::decode_pooled_streamed`]) and the first source's decode
/// overlaps the last source's network wait. Multi-chunk staging buffers
/// come from `staging` (the decode pool, closing the recycle loop).
///
/// Protocol assumption (held by the engine's collective-gated iteration
/// loop): at most one in-flight batched message per source on `tag`.
/// Frames from sources outside `srcs` are reassembled and dropped
/// (debug-asserted — they indicate a stale stream).
///
/// [`Codec::decode_pooled_streamed`]: crate::io::codec::Codec::decode_pooled_streamed
pub fn recv_all_batched_streaming(
    re: &mut Reassembler,
    comm: &mut Communicator,
    srcs: &[u32],
    tag: Tag,
    staging: &mut ViewPool,
    mut complete: impl FnMut(usize, WireSlot),
) -> RecvAllStats {
    let mut stats = RecvAllStats::default();
    re.done_scratch.clear();
    re.done_scratch.resize(srcs.len(), false);
    let mut pending = srcs.len();
    while pending > 0 {
        let (m, waited) = comm.recv_any_timed(tag);
        stats.wait_secs += waited;
        stats.frames += 1;
        let t = crate::util::timing::CpuTimer::start();
        let fed = match srcs.iter().position(|&s| s == m.src) {
            Some(k) => match re.feed_frame(m.src, m.tag, m.data, staging) {
                Ok(done) => done.map(|(_, slot)| (k, slot)),
                Err(e) => {
                    // A corrupt frame on the clean (non-injected) path
                    // indicates a local bug; counted either way, and the
                    // reliable path is the one that NACKs.
                    debug_assert!(false, "corrupt frame on fault-free link: {e}");
                    stats.faults_detected += 1;
                    None
                }
            },
            None => {
                debug_assert!(false, "aura frame from unexpected source {}", m.src);
                // Reassemble and drop so the stale stream can't poison
                // the partial map.
                if let Ok(Some((_, slot))) = re.feed_frame(m.src, m.tag, m.data, staging) {
                    slot.recycle_into(staging);
                }
                None
            }
        };
        if let Some((_, slot)) = &fed {
            if let WireSlot::Staged(buf) = slot {
                stats.copied_bytes += buf.len() as u64;
            }
        }
        stats.reassembly_secs += t.elapsed_secs();
        if let Some((k, slot)) = fed {
            debug_assert!(!re.done_scratch[k], "second message completed for src {}", m.src);
            if !re.done_scratch[k] {
                re.done_scratch[k] = true;
                pending -= 1;
                complete(k, slot);
            }
        }
    }
    stats
}

/// [`recv_all_batched_streaming`] without the streaming consumer: every
/// completed wire parks in its source's slot (`wires[k]` for `srcs[k]`,
/// deterministic source order regardless of delivery order). Kept for
/// callers that genuinely need all wires before acting; the engine uses
/// the streaming form.
pub fn recv_all_batched_into(
    re: &mut Reassembler,
    comm: &mut Communicator,
    srcs: &[u32],
    tag: Tag,
    wires: &mut [WireSlot],
    staging: &mut ViewPool,
) -> RecvAllStats {
    assert_eq!(srcs.len(), wires.len(), "one wire slot per source");
    recv_all_batched_streaming(re, comm, srcs, tag, staging, |k, slot| wires[k] = slot)
}

/// Retry policy for [`recv_all_batched_reliable`]: how long each bounded
/// wait slice lasts, and how many slices may elapse before the call gives
/// up with [`CommError::RetriesExhausted`]. Every slice that expires
/// without completing the exchange NACKs all still-incomplete sources.
#[derive(Clone, Copy, Debug)]
pub struct RetryConfig {
    pub slice: Duration,
    pub max_slices: u32,
}

impl Default for RetryConfig {
    fn default() -> Self {
        // 2 ms × 2000 ≈ 4 s worst case before declaring a peer dead —
        // far beyond any in-process delivery delay, short enough for
        // tests to observe exhaustion.
        RetryConfig { slice: Duration::from_millis(2), max_slices: 2000 }
    }
}

/// The loss-tolerant form of [`recv_all_batched_streaming`]: collect one
/// complete message **with id `msg_id`** from each of `srcs` on `tag`,
/// surviving dropped, delayed, duplicated, reordered, and corrupted
/// frames.
///
/// The recovery ladder, per wait slice:
/// 1. serve peers' retransmission requests ([`Communicator::
///    service_retry_queue`]) so two ranks blocked in this call cannot
///    deadlock each other;
/// 2. receive with a bounded deadline; a corrupt frame is dropped,
///    counted, and NACKed immediately; a completed message whose id is
///    not `msg_id` (or whose source already finished) is stale — its
///    storage recycles and the wait continues;
/// 3. on slice expiry, NACK every incomplete source and try again, up to
///    `cfg.max_slices` slices.
///
/// Retransmitted frames are the sender's archived originals — same
/// bytes, same sequence numbers — so a recovered exchange is
/// bit-identical to a fault-free one. Once a source completes, its
/// leftover partial streams purge (late duplicates of finished messages
/// must not pin pool frames).
#[allow(clippy::too_many_arguments)]
pub fn recv_all_batched_reliable(
    re: &mut Reassembler,
    comm: &mut Communicator,
    srcs: &[u32],
    tag: Tag,
    msg_id: u32,
    staging: &mut ViewPool,
    cfg: RetryConfig,
    mut complete: impl FnMut(usize, WireSlot),
) -> Result<RecvAllStats, CommError> {
    let mut stats = RecvAllStats::default();
    re.done_scratch.clear();
    re.done_scratch.resize(srcs.len(), false);
    let mut pending = srcs.len();
    let mut slices_used = 0u32;
    while pending > 0 {
        comm.service_retry_queue();
        let m = match comm.recv_any_deadline(tag, cfg.slice) {
            Ok((m, waited)) => {
                stats.wait_secs += waited;
                m
            }
            Err(CommError::Timeout { waited_secs, .. }) => {
                stats.wait_secs += waited_secs;
                slices_used += 1;
                // While sitting in a long wait (e.g. waiting out a dead
                // peer's silence) keep the liveness plane warm: peers
                // stalled on *this* rank must not mistake the stall for
                // death. Every 32nd empty slice (~64 ms at the default
                // 2 ms slice) is frequent enough for any sane death
                // timeout without flooding mailboxes.
                if slices_used % 32 == 0 {
                    comm.send_heartbeats();
                }
                let missing: Vec<u32> = srcs
                    .iter()
                    .enumerate()
                    .filter(|(k, _)| !re.done_scratch[*k])
                    .map(|(_, &s)| s)
                    .collect();
                // Liveness escalation (empty when the plane is off, so
                // the plain retries-exhausted path is untouched): once
                // every still-missing source has been silent past the
                // death timeout, retrying is pointless — declare them
                // dead and hand the failure to the reshard rung. A mix of
                // overdue and merely-slow sources keeps retrying until
                // the budget runs out, then escalates if any are overdue.
                let dead = comm.overdue(&missing);
                let escalate =
                    dead.len() == missing.len() || (slices_used >= cfg.max_slices && !dead.is_empty());
                if escalate {
                    for &d in &dead {
                        comm.mark_dead(d);
                    }
                    return Err(CommError::RankDead { tag, dead });
                }
                if slices_used >= cfg.max_slices {
                    return Err(CommError::RetriesExhausted { tag, pending: missing });
                }
                for (k, &s) in srcs.iter().enumerate() {
                    if !re.done_scratch[k] {
                        comm.request_retry(s, tag, msg_id);
                        stats.retries_sent += 1;
                    }
                }
                continue;
            }
            Err(e) => return Err(e),
        };
        stats.frames += 1;
        let t = crate::util::timing::CpuTimer::start();
        let k = srcs.iter().position(|&s| s == m.src);
        let fed = re.feed_frame(m.src, m.tag, m.data, staging);
        stats.reassembly_secs += t.elapsed_secs();
        match (k, fed) {
            (Some(k), Ok(Some((id, slot)))) => {
                if id != msg_id || re.done_scratch[k] {
                    // A duplicate of a finished message, or a retransmit
                    // of a superseded one.
                    stats.stale_dropped += 1;
                    slot.recycle_into(staging);
                } else {
                    if let WireSlot::Staged(buf) = &slot {
                        stats.copied_bytes += buf.len() as u64;
                    }
                    re.done_scratch[k] = true;
                    pending -= 1;
                    re.purge(m.src, tag);
                    complete(k, slot);
                }
            }
            (Some(_), Ok(None)) => {}
            (Some(_), Err(_)) => {
                // Corrupt frame: condemned and already counted by the
                // reassembler; ask for the whole message again (duplicate
                // chunks of it will be suppressed).
                stats.faults_detected += 1;
                comm.request_retry(m.src, tag, msg_id);
                stats.retries_sent += 1;
            }
            (None, Ok(Some((_, slot)))) => {
                stats.stale_dropped += 1;
                slot.recycle_into(staging);
            }
            (None, _) => {}
        }
    }
    Ok(stats)
}

impl Reassembler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Track the link's sequence number (observational: counts gaps and
    /// late arrivals, never rejects — retransmits legitimately reuse
    /// their original number).
    fn note_seq(&mut self, src: u32, tag: Tag, seq: u32) {
        let e = self.expected_seq.entry((src, tag)).or_insert(0);
        if seq == *e {
            *e = e.wrapping_add(1);
        } else if seq.wrapping_sub(*e) < u32::MAX / 2 {
            // Ahead of expectation: something earlier is missing.
            self.faults.seq_gaps += 1;
            *e = seq.wrapping_add(1);
        } else {
            // Behind expectation: a late, reordered, or retransmitted
            // frame filling in.
            self.faults.out_of_order += 1;
        }
    }

    /// Park one chunk frame; returns the stream's chunk frames once all
    /// have arrived. Duplicate chunks are suppressed (counted, frame
    /// dropped); a total that disagrees with the stream's is an error.
    fn stash_chunk(
        &mut self,
        src: u32,
        tag: Tag,
        msg_id: u32,
        chunk: u32,
        total: u32,
        frame: Frame,
    ) -> Result<Option<Vec<Option<Frame>>>, FrameError> {
        let Reassembler { partial, chunk_scratch, faults, .. } = self;
        let key = (src, tag, msg_id);
        let entry = partial.entry(key).or_insert_with(|| {
            let mut v = chunk_scratch.pop().unwrap_or_default();
            v.clear();
            v.resize_with(total as usize, || None);
            (v, total)
        });
        if entry.1 != total {
            faults.bad_geometry += 1;
            return Err(FrameError::InconsistentTotal { expected: entry.1, got: total });
        }
        if entry.0[chunk as usize].is_some() {
            // Retransmission overlap: the original and the retried copy
            // both arrived. Keep the first, drop this one.
            faults.duplicates += 1;
            return Ok(None);
        }
        // The frame is parked whole (body offset fixed by the header
        // size) — chunks stay in the sender's published buffers until
        // the one assembly pass.
        entry.0[chunk as usize] = Some(frame);
        if entry.0.iter().all(|c| c.is_some()) {
            let (chunks, _) = partial.remove(&key).expect("entry was just inserted or found");
            Ok(Some(chunks))
        } else {
            Ok(None)
        }
    }

    /// Drop every partial stream parked for `(src, tag)` — called once a
    /// message completes on the reliable path, where late retransmitted
    /// chunks of already-finished (or superseded) messages would
    /// otherwise accumulate as streams that never complete. The parked
    /// frames recycle into the transport pool as they drop.
    pub fn purge(&mut self, src: u32, tag: Tag) -> usize {
        let keys: Vec<(u32, Tag, u32)> =
            self.partial.keys().filter(|(s, t, _)| *s == src && *t == tag).copied().collect();
        for key in &keys {
            if let Some((chunks, _)) = self.partial.remove(key) {
                self.recycle_chunks(chunks);
            }
        }
        keys.len()
    }

    fn recycle_chunks(&mut self, mut chunks: Vec<Option<Frame>>) {
        chunks.clear();
        self.chunk_scratch.push(chunks);
    }

    /// Feed one received frame. A single-chunk message completes with
    /// **zero copies** — the returned [`WireSlot::Direct`] *is* the
    /// published frame. A multi-chunk stream completes by assembling the
    /// chunk bodies once into a buffer from `staging`
    /// ([`WireSlot::Staged`]); the spent chunk frames recycle into the
    /// transport pool as they drop.
    pub fn feed_frame(
        &mut self,
        src: u32,
        tag: Tag,
        frame: Frame,
        staging: &mut ViewPool,
    ) -> Result<Option<(u32, WireSlot)>, FrameError> {
        let (msg_id, chunk, total, seq) = self.verify(src, tag, &frame)?;
        self.note_seq(src, tag, seq);
        if total == 1 {
            debug_assert_eq!(chunk, 0);
            return Ok(Some((msg_id, WireSlot::Direct(frame))));
        }
        let Some(mut chunks) = self.stash_chunk(src, tag, msg_id, chunk, total, frame)? else {
            return Ok(None);
        };
        let mut buf = staging.take_buf();
        buf.clear();
        let bytes: usize = chunks
            .iter()
            .map(|c| c.as_ref().expect("complete stream has every chunk").len() - FRAME_HEADER)
            .sum();
        buf.reserve(bytes);
        for c in chunks.iter_mut() {
            let f = c.take().expect("complete stream has every chunk");
            buf.extend_from_slice(&f[FRAME_HEADER..]);
        }
        self.recycle_chunks(chunks);
        Ok(Some((msg_id, WireSlot::Staged(buf))))
    }

    /// Integrity-check one frame, metering the checksum time and the
    /// fault counters.
    fn verify(&mut self, _src: u32, _tag: Tag, frame: &Frame) -> Result<(u32, u32, u32, u32), FrameError> {
        let t = CpuTimer::start();
        let parsed = verify_header(frame);
        self.checksum_secs += t.elapsed_secs();
        match &parsed {
            Err(FrameError::Short { .. }) => self.faults.short_frames += 1,
            Err(FrameError::BadCrc { .. }) => self.faults.crc_failures += 1,
            Err(_) => self.faults.bad_geometry += 1,
            Ok(_) => {}
        }
        parsed
    }

    /// Feed one received frame; returns the full payload once complete
    /// (copying convenience wrapper around the frame-granular path).
    pub fn feed(
        &mut self,
        src: u32,
        tag: Tag,
        frame: Frame,
    ) -> Result<Option<(u32, Vec<u8>)>, FrameError> {
        let mut out = Vec::new();
        Ok(self.feed_into(src, tag, frame, &mut out)?.map(|id| (id, out)))
    }

    /// Feed one received frame, assembling the completed payload into a
    /// caller-owned buffer (cleared first; capacity reused across
    /// messages). This is the *copying* legacy surface — the streaming
    /// receive path hands out [`WireSlot`]s via
    /// [`Reassembler::feed_frame`] instead and copies nothing for
    /// single-chunk messages.
    pub fn feed_into(
        &mut self,
        src: u32,
        tag: Tag,
        frame: Frame,
        out: &mut Vec<u8>,
    ) -> Result<Option<u32>, FrameError> {
        let (msg_id, chunk, total, seq) = self.verify(src, tag, &frame)?;
        self.note_seq(src, tag, seq);
        if total == 1 {
            debug_assert_eq!(chunk, 0);
            out.clear();
            out.extend_from_slice(&frame[FRAME_HEADER..]);
            return Ok(Some(msg_id));
        }
        let Some(mut chunks) = self.stash_chunk(src, tag, msg_id, chunk, total, frame)? else {
            return Ok(None);
        };
        out.clear();
        for c in chunks.iter_mut() {
            let f = c.take().expect("complete stream has every chunk");
            out.extend_from_slice(&f[FRAME_HEADER..]);
        }
        self.recycle_chunks(chunks);
        Ok(Some(msg_id))
    }

    /// Receive a complete batched message from `src` on `tag` (blocking).
    pub fn recv_batched(&mut self, comm: &mut Communicator, src: u32, tag: Tag) -> (u32, Vec<u8>) {
        let mut out = Vec::new();
        let id = self.recv_batched_into(comm, src, tag, &mut out);
        (id, out)
    }

    /// [`Reassembler::recv_batched`] into a caller-owned buffer, for
    /// fixed-source receive loops. A corrupt frame is counted and dropped
    /// (debug-asserted: the blocking legacy path is only used on links
    /// without fault injection, where corruption indicates a local bug);
    /// the loop keeps waiting for a clean copy.
    pub fn recv_batched_into(
        &mut self,
        comm: &mut Communicator,
        src: u32,
        tag: Tag,
        out: &mut Vec<u8>,
    ) -> u32 {
        loop {
            let m = comm.recv(Some(src), Some(tag));
            match self.feed_into(m.src, m.tag, m.data, out) {
                Ok(Some(id)) => return id,
                Ok(None) => {}
                Err(e) => debug_assert!(false, "corrupt frame on fault-free link: {e}"),
            }
        }
    }

    /// Number of incomplete streams (diagnostics).
    pub fn pending(&self) -> usize {
        self.partial.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::mpi::MpiWorld;
    use crate::comm::network::NetworkModel;
    use crate::util::Rng;
    use std::sync::Arc;

    fn empty_slots(n: usize) -> Vec<WireSlot> {
        std::iter::repeat_with(WireSlot::default).take(n).collect()
    }

    #[test]
    fn single_chunk_round_trip() {
        let world = MpiWorld::new(2, NetworkModel::ideal());
        let mut tx = world.communicator(0);
        let mut rx = world.communicator(1);
        let n = send_batched(&mut tx, 1, 7, 1, b"hello", 1024);
        assert_eq!(n, 1);
        let mut re = Reassembler::new();
        let (id, data) = re.recv_batched(&mut rx, 0, 7);
        assert_eq!(id, 1);
        assert_eq!(data, b"hello");
        assert_eq!(re.pending(), 0);
    }

    #[test]
    fn multi_chunk_round_trip() {
        let world = MpiWorld::new(2, NetworkModel::ideal());
        let mut tx = world.communicator(0);
        let mut rx = world.communicator(1);
        let mut rng = Rng::new(5);
        let data: Vec<u8> = (0..10_000).map(|_| rng.next_u64() as u8).collect();
        let n = send_batched(&mut tx, 1, 7, 42, &data, 1024);
        assert_eq!(n, 10);
        let mut re = Reassembler::new();
        let (id, got) = re.recv_batched(&mut rx, 0, 7);
        assert_eq!(id, 42);
        assert_eq!(got, data);
    }

    #[test]
    fn empty_message_still_frames() {
        let world = MpiWorld::new(2, NetworkModel::ideal());
        let mut tx = world.communicator(0);
        let mut rx = world.communicator(1);
        send_batched(&mut tx, 1, 7, 9, &[], 1024);
        let mut re = Reassembler::new();
        let (id, got) = re.recv_batched(&mut rx, 0, 7);
        assert_eq!(id, 9);
        assert!(got.is_empty());
    }

    #[test]
    fn framed_send_publishes_single_chunk_without_copy() {
        let world = MpiWorld::new(2, NetworkModel::ideal());
        let mut tx = world.communicator(0);
        let mut rx = world.communicator(1);
        let mut wire = vec![0u8; FRAME_HEADER];
        wire.extend_from_slice(b"framed body");
        let body_ptr = wire[FRAME_HEADER..].as_ptr();
        let n = send_batched_framed(&mut tx, 1, 7, 3, &mut wire, 1024);
        assert_eq!(n, 1);
        // The caller's buffer was swapped for a pool lease.
        assert!(wire.is_empty());
        let (m, _) = rx.recv_any_timed(7);
        let mut re = Reassembler::new();
        let mut staging = ViewPool::new();
        let (id, slot) = re.feed_frame(m.src, m.tag, m.data, &mut staging).unwrap().unwrap();
        assert_eq!(id, 3);
        assert_eq!(slot.as_wire(), b"framed body");
        // Zero-copy end to end: the decoder-visible bytes live at the
        // very address the sender wrote them to.
        assert_eq!(slot.as_wire().as_ptr(), body_ptr);
    }

    #[test]
    fn framed_send_chunks_large_wires_and_keeps_the_buffer() {
        let world = MpiWorld::new(2, NetworkModel::ideal());
        let mut tx = world.communicator(0);
        let mut rx = world.communicator(1);
        let body: Vec<u8> = (0..3000u32).map(|i| i as u8).collect();
        let mut wire = vec![0u8; FRAME_HEADER];
        wire.extend_from_slice(&body);
        let n = send_batched_framed(&mut tx, 1, 7, 8, &mut wire, 1000);
        assert_eq!(n, 3);
        assert_eq!(wire.len(), FRAME_HEADER + body.len(), "multi-chunk send keeps the wire");
        let mut re = Reassembler::new();
        let mut staging = ViewPool::new();
        let mut got = None;
        while got.is_none() {
            let (m, _) = rx.recv_any_timed(7);
            got = re.feed_frame(m.src, m.tag, m.data, &mut staging).unwrap();
        }
        let (id, slot) = got.unwrap();
        assert_eq!(id, 8);
        assert_eq!(slot.as_wire(), &body[..]);
        assert!(matches!(slot, WireSlot::Staged(_)));
        slot.recycle_into(&mut staging);
        assert!(staging.approx_bytes() > 0, "staging buffer must recycle");
    }

    #[test]
    fn interleaved_streams_reassemble_independently() {
        let world = MpiWorld::new(3, NetworkModel::ideal());
        let mut a = world.communicator(0);
        let mut b = world.communicator(1);
        let mut rx = world.communicator(2);
        let da = vec![1u8; 3000];
        let db = vec![2u8; 3000];
        send_batched(&mut a, 2, 7, 1, &da, 1000);
        send_batched(&mut b, 2, 7, 1, &db, 1000);
        let mut re = Reassembler::new();
        let mut done = Vec::new();
        while done.len() < 2 {
            let m = rx.recv(None, Some(7));
            let src = m.src;
            if let Ok(Some((_, data))) = re.feed(src, m.tag, m.data) {
                done.push((src, data));
            }
        }
        done.sort_by_key(|(s, _)| *s);
        assert_eq!(done[0].1, da);
        assert_eq!(done[1].1, db);
    }

    #[test]
    fn recv_batched_into_reuses_buffer() {
        let world = MpiWorld::new(2, NetworkModel::ideal());
        let mut tx = world.communicator(0);
        let mut rx = world.communicator(1);
        let mut re = Reassembler::new();
        let mut out = Vec::new();
        for round in 0u32..4 {
            let data = vec![round as u8; 2500];
            send_batched(&mut tx, 1, 7, round, &data, 1000);
            let id = re.recv_batched_into(&mut rx, 0, 7, &mut out);
            assert_eq!(id, round);
            assert_eq!(out, data);
        }
        let cap = out.capacity();
        send_batched(&mut tx, 1, 7, 9, &[1, 2, 3], 1000);
        re.recv_batched_into(&mut rx, 0, 7, &mut out);
        assert_eq!(out, vec![1, 2, 3]);
        assert_eq!(out.capacity(), cap, "steady-state receive must not realloc");
    }

    #[test]
    fn recv_all_collects_every_source_in_any_arrival_order() {
        // Three senders, chunked wires, three adversarial delivery orders
        // (all sends happen before the receiver starts, so the mailbox
        // arrival order IS the send order below). Results must land in
        // source order regardless.
        let payload = |s: u32| -> Vec<u8> { vec![s as u8; 700 * (s as usize + 1)] };
        let orders: [[u32; 3]; 3] = [[1, 2, 3], [3, 2, 1], [2, 3, 1]];
        for order in orders {
            let world = MpiWorld::new(4, NetworkModel::ideal());
            let mut rx = world.communicator(0);
            for &s in &order {
                let mut tx = world.communicator(s);
                send_batched(&mut tx, 0, 7, 11, &payload(s), 256);
            }
            let mut re = Reassembler::new();
            let srcs = [1u32, 2, 3];
            let mut staging = ViewPool::new();
            let mut wires = empty_slots(3);
            let stats =
                recv_all_batched_into(&mut re, &mut rx, &srcs, 7, &mut wires, &mut staging);
            for (k, &s) in srcs.iter().enumerate() {
                assert_eq!(wires[k].as_wire(), &payload(s)[..], "order {order:?}, src {s}");
            }
            // Frames: ceil(700(s+1)/256) per source; every chunked stream
            // is staged, so the copied bytes are the full payloads.
            let expect_frames: u64 = (1..=3u64).map(|s| (700 * (s + 1)).div_ceil(256)).sum();
            assert_eq!(stats.frames, expect_frames);
            let expect_bytes: u64 = (1..=3u64).map(|s| 700 * (s + 1)).sum();
            assert_eq!(stats.copied_bytes, expect_bytes);
            assert_eq!(re.pending(), 0);
        }
    }

    #[test]
    fn recv_all_single_frame_messages_copy_nothing() {
        let world = MpiWorld::new(3, NetworkModel::ideal());
        let mut rx = world.communicator(0);
        for s in [1u32, 2] {
            let mut tx = world.communicator(s);
            send_batched(&mut tx, 0, 7, 5, &[s as u8; 100], 1024);
        }
        let mut re = Reassembler::new();
        let mut staging = ViewPool::new();
        let mut wires = empty_slots(2);
        let stats = recv_all_batched_into(&mut re, &mut rx, &[1, 2], 7, &mut wires, &mut staging);
        assert_eq!(stats.copied_bytes, 0, "single-frame wires must be handed over in place");
        for (k, s) in [1u8, 2].iter().enumerate() {
            assert!(matches!(wires[k], WireSlot::Direct(_)));
            assert_eq!(wires[k].as_wire(), &vec![*s; 100][..]);
        }
        // Dropping the slots returns the frames to the transport pool.
        wires.clear();
        assert_eq!(world.frame_pool().stats().outstanding, 0);
    }

    #[test]
    fn streaming_receive_completes_as_wires_arrive_not_in_slot_order() {
        // All three wires are queued before the receiver starts; the
        // streaming consumer must see one completion per take, served in
        // the mailbox's fair rotation across sources (cursor order), not
        // gated on slot 0 finishing first. A flooding source can
        // therefore never starve the others' completions — the recv_any
        // fairness contract.
        let world = MpiWorld::new(4, NetworkModel::ideal());
        let mut rx = world.communicator(0);
        for &s in &[3u32, 2, 1] {
            let mut tx = world.communicator(s);
            send_batched(&mut tx, 0, 7, 1, &[s as u8; 50], 1024);
        }
        let mut re = Reassembler::new();
        let mut staging = ViewPool::new();
        let mut seen = Vec::new();
        recv_all_batched_streaming(&mut re, &mut rx, &[1, 2, 3], 7, &mut staging, |k, slot| {
            assert_eq!(slot.as_wire()[0] as usize, k + 1, "slot index must map to source");
            seen.push(k);
        });
        // Rotation starts below the receiver's own rank and rises: the
        // single-frame wires complete in source order 1, 2, 3.
        assert_eq!(seen, vec![0, 1, 2], "completions must stream in rotation order");
    }

    #[test]
    fn recv_all_overlaps_blocking_with_late_senders() {
        // The receiver starts before the last sender has sent anything;
        // it must ingest the early wires and block only for the rest.
        // The late send is gated on a rendezvous the receiver fires just
        // before entering the receive loop, so the blocked wait cannot be
        // raced away by a descheduled receiver (the mpi.rs
        // recv_any_timed test's handshake pattern).
        const RDV: Tag = 99;
        let world = MpiWorld::new(3, NetworkModel::ideal());
        let mut early = world.communicator(1);
        let data1 = vec![1u8; 5000];
        send_batched(&mut early, 0, 7, 3, &data1, 1024);
        let world2 = Arc::clone(&world);
        let late = std::thread::spawn(move || {
            let mut tx = world2.communicator(2);
            tx.recv(Some(0), Some(RDV));
            std::thread::sleep(std::time::Duration::from_millis(20));
            send_batched(&mut tx, 0, 7, 3, &[42u8; 100], 1024);
        });
        let mut rx = world.communicator(0);
        let mut re = Reassembler::new();
        let mut staging = ViewPool::new();
        let mut wires = empty_slots(2);
        rx.isend(2, RDV, vec![0]);
        let stats = recv_all_batched_into(&mut re, &mut rx, &[1, 2], 7, &mut wires, &mut staging);
        late.join().unwrap();
        assert_eq!(wires[0].as_wire(), &data1[..]);
        assert_eq!(wires[1].as_wire(), &[42u8; 100][..]);
        assert!(stats.wait_secs > 0.0, "blocked wait on the late sender must be visible");
    }

    #[test]
    fn reassembler_scratch_recycles_across_streams() {
        let world = MpiWorld::new(2, NetworkModel::ideal());
        let mut tx = world.communicator(0);
        let mut rx = world.communicator(1);
        let mut re = Reassembler::new();
        let mut staging = ViewPool::new();
        let data = vec![9u8; 4000];
        for round in 0u32..6 {
            send_batched(&mut tx, 1, 7, round, &data, 1000);
            let mut got = None;
            while got.is_none() {
                let (m, _) = rx.recv_any_timed(7);
                got = re.feed_frame(m.src, m.tag, m.data, &mut staging).unwrap();
            }
            let (id, slot) = got.unwrap();
            assert_eq!(id, round);
            assert_eq!(slot.as_wire(), &data[..]);
            slot.recycle_into(&mut staging);
        }
        assert_eq!(re.pending(), 0);
        // The chunk-slot scratch and every transport frame recycled.
        assert_eq!(world.frame_pool().stats().outstanding, 0);
    }

    #[test]
    fn corrupt_frames_are_rejected_and_counted() {
        let world = MpiWorld::new(2, NetworkModel::ideal());
        let mut tx = world.communicator(0);
        let mut rx = world.communicator(1);
        let mut re = Reassembler::new();
        let mut staging = ViewPool::new();

        // Body bit-flip.
        send_batched(&mut tx, 1, 7, 1, b"payload bytes", 1024);
        let m = rx.recv(Some(0), Some(7));
        let mut bytes = m.data.to_vec();
        bytes[FRAME_HEADER + 3] ^= 0x10;
        let err = re.feed_frame(0, 7, Frame::owned(bytes), &mut staging).unwrap_err();
        assert!(matches!(err, FrameError::BadCrc { .. }));

        // Header bit-flip (msg_id field) — caught because the CRC covers
        // the header too.
        send_batched(&mut tx, 1, 7, 2, b"payload bytes", 1024);
        let m = rx.recv(Some(0), Some(7));
        let mut bytes = m.data.to_vec();
        bytes[1] ^= 0x01;
        let err = re.feed_frame(0, 7, Frame::owned(bytes), &mut staging).unwrap_err();
        assert!(matches!(err, FrameError::BadCrc { .. }));

        // Truncation below the header.
        let err = re.feed_frame(0, 7, Frame::owned(vec![0u8; 5]), &mut staging).unwrap_err();
        assert_eq!(err, FrameError::Short { len: 5 });

        // Truncation into the body.
        send_batched(&mut tx, 1, 7, 3, b"payload bytes", 1024);
        let m = rx.recv(Some(0), Some(7));
        let bytes = m.data.to_vec();
        let cut = Frame::owned(bytes[..bytes.len() - 4].to_vec());
        let err = re.feed_frame(0, 7, cut, &mut staging).unwrap_err();
        assert!(matches!(err, FrameError::BadCrc { .. }));

        assert_eq!(re.faults.crc_failures, 3);
        assert_eq!(re.faults.short_frames, 1);
        assert_eq!(re.faults.frames_rejected(), 4);
        assert!(re.checksum_secs >= 0.0);
    }

    #[test]
    fn clean_frames_verify_and_count_nothing() {
        let world = MpiWorld::new(2, NetworkModel::ideal());
        let mut tx = world.communicator(0);
        let mut rx = world.communicator(1);
        let mut re = Reassembler::new();
        let mut staging = ViewPool::new();
        for id in 0u32..4 {
            send_batched(&mut tx, 1, 7, id, &[id as u8; 300], 1024);
            let m = rx.recv(Some(0), Some(7));
            let (got, slot) = re.feed_frame(m.src, m.tag, m.data, &mut staging).unwrap().unwrap();
            assert_eq!(got, id);
            assert_eq!(slot.as_wire(), &[id as u8; 300][..]);
        }
        assert_eq!(re.faults, ReassemblyFaults::default());
    }

    #[test]
    fn sequence_gaps_and_late_arrivals_are_observed_not_rejected() {
        let world = MpiWorld::new(2, NetworkModel::ideal());
        let mut tx = world.communicator(0);
        let mut rx = world.communicator(1);
        for id in 0u32..3 {
            send_batched(&mut tx, 1, 7, id, &[id as u8; 10], 1024);
        }
        let mut re = Reassembler::new();
        let mut staging = ViewPool::new();
        let m0 = rx.recv(Some(0), Some(7));
        let m1 = rx.recv(Some(0), Some(7));
        let m2 = rx.recv(Some(0), Some(7));
        // Deliver seq 0, then seq 2 (gap), then seq 1 (late fill-in) —
        // every frame is still accepted.
        for m in [m0, m2, m1] {
            assert!(re.feed_frame(0, 7, m.data, &mut staging).unwrap().is_some());
        }
        assert_eq!(re.faults.seq_gaps, 1);
        assert_eq!(re.faults.out_of_order, 1);
        assert_eq!(re.faults.frames_rejected(), 0);
    }

    #[test]
    fn reliable_recv_recovers_dropped_frames_via_retransmission() {
        use crate::comm::chaos::FaultPlan;
        const DONE: Tag = 99;
        let world = MpiWorld::new(2, NetworkModel::ideal());
        let world2 = Arc::clone(&world);
        let data = vec![7u8; 500];
        let expect = data.clone();
        let sender = std::thread::spawn(move || {
            let mut tx = world2.communicator(0);
            // Drop exactly the first data frame, then behave perfectly.
            tx.install_chaos(FaultPlan::none(9).with_drop(1.0).with_max_faults(1));
            send_batched(&mut tx, 1, 7, 1, &data, 1024);
            // Serve NACKs until the receiver confirms completion.
            loop {
                tx.service_retry_queue();
                if tx.try_recv(Some(1), Some(DONE)).is_some() {
                    break;
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            (tx.retransmits_served(), tx.chaos_stats())
        });
        let mut rx = world.communicator(1);
        let mut re = Reassembler::new();
        let mut staging = ViewPool::new();
        let mut got = None;
        let cfg = RetryConfig { slice: Duration::from_millis(2), max_slices: 500 };
        let stats =
            recv_all_batched_reliable(&mut re, &mut rx, &[0], 7, 1, &mut staging, cfg, |k, slot| {
                assert_eq!(k, 0);
                got = Some(slot);
            })
            .expect("exchange must recover");
        rx.isend(0, DONE, vec![1]);
        let (served, chaos) = sender.join().unwrap();
        assert_eq!(got.expect("message delivered").as_wire(), &expect[..]);
        assert_eq!(chaos.dropped, 1, "the plan injected exactly one drop");
        assert!(served >= 1, "the drop must have been healed by a retransmit");
        assert!(stats.retries_sent >= 1, "recovery must have been NACK-driven");
    }

    #[test]
    fn reliable_recv_suppresses_duplicate_chunks() {
        use crate::comm::chaos::FaultPlan;
        let world = MpiWorld::new(2, NetworkModel::ideal());
        let mut tx = world.communicator(0);
        // Duplicate exactly one frame of a two-chunk message.
        tx.install_chaos(FaultPlan::none(4).with_duplicate(1.0).with_max_faults(1));
        let data: Vec<u8> = (0..2000u32).map(|i| i as u8).collect();
        send_batched(&mut tx, 1, 7, 5, &data, 1024);
        let mut rx = world.communicator(1);
        let mut re = Reassembler::new();
        let mut staging = ViewPool::new();
        let mut got = None;
        let cfg = RetryConfig { slice: Duration::from_millis(2), max_slices: 50 };
        recv_all_batched_reliable(&mut re, &mut rx, &[0], 7, 5, &mut staging, cfg, |_, slot| {
            got = Some(slot);
        })
        .expect("exchange must complete");
        assert_eq!(got.expect("message delivered").as_wire(), &data[..]);
        assert_eq!(re.faults.duplicates, 1, "the duplicate chunk must be suppressed");
    }

    #[test]
    fn reliable_recv_gives_up_when_the_peer_is_silent() {
        let world = MpiWorld::new(2, NetworkModel::ideal());
        let mut rx = world.communicator(1);
        let mut re = Reassembler::new();
        let mut staging = ViewPool::new();
        let cfg = RetryConfig { slice: Duration::from_millis(1), max_slices: 3 };
        let err = recv_all_batched_reliable(&mut re, &mut rx, &[0], 7, 1, &mut staging, cfg, |_, _| {
            panic!("nothing can complete");
        })
        .unwrap_err();
        assert_eq!(err, CommError::RetriesExhausted { tag: 7, pending: vec![0] });
    }

    #[test]
    fn reliable_recv_escalates_a_silent_peer_to_rank_dead_with_liveness_on() {
        let world = MpiWorld::new(2, NetworkModel::ideal());
        let mut rx = world.communicator(1);
        rx.enable_liveness(Duration::from_millis(20));
        let mut re = Reassembler::new();
        let mut staging = ViewPool::new();
        // Budget far larger than the death timeout: escalation must come
        // from liveness, not from retry exhaustion.
        let cfg = RetryConfig { slice: Duration::from_millis(2), max_slices: 1000 };
        let t0 = std::time::Instant::now();
        let err = recv_all_batched_reliable(&mut re, &mut rx, &[0], 7, 1, &mut staging, cfg, |_, _| {
            panic!("nothing can complete");
        })
        .unwrap_err();
        assert_eq!(err, CommError::RankDead { tag: 7, dead: vec![0] });
        assert!(
            t0.elapsed() < Duration::from_millis(1500),
            "escalation must come from the death timeout, not the 2s retry budget"
        );
        assert!(rx.is_dead(0), "escalation marks the peer dead");
        assert_eq!(rx.dead_ranks(), vec![0]);
    }

    #[test]
    fn world_handle_is_shareable() {
        let world = MpiWorld::new(2, NetworkModel::ideal());
        let w2 = Arc::clone(&world);
        assert_eq!(w2.size(), 2);
    }
}
