//! Large-message batching (§2.4.3: "we transmit large messages in smaller
//! batches to reduce the memory needed for transmission buffers,
//! compression, and serialization").
//!
//! A payload larger than the configured chunk size is split into numbered
//! chunks carried under [`tags::CHUNK`]-style framing; the receiver
//! reassembles them in order. Framing: `[msg_id u32][chunk u32][total u32]
//! [bytes...]`.

use super::mpi::{Communicator, Tag};
use std::collections::HashMap;

/// Default chunk size (1 MiB) — bounds peak transmission-buffer memory.
pub const DEFAULT_CHUNK_BYTES: usize = 1 << 20;

const FRAME_HEADER: usize = 12;

/// Sender side: split `data` into frames and send them to `dst` on `tag`.
/// `msg_id` must be unique per (sender, receiver, tag) stream position —
/// the engine uses its iteration counter.
///
/// The caller keeps ownership of `data` (the codec's reused wire buffer);
/// each frame is a scatter-gather send of the stack header plus a chunk
/// slice, so the payload is never staged through an intermediate frame
/// buffer.
pub fn send_batched(
    comm: &mut Communicator,
    dst: u32,
    tag: Tag,
    msg_id: u32,
    data: &[u8],
    chunk_bytes: usize,
) -> usize {
    let chunk_bytes = chunk_bytes.max(1);
    let total = data.len().div_ceil(chunk_bytes).max(1) as u32;
    let header = |chunk: u32| -> [u8; FRAME_HEADER] {
        let mut h = [0u8; FRAME_HEADER];
        h[0..4].copy_from_slice(&msg_id.to_le_bytes());
        h[4..8].copy_from_slice(&chunk.to_le_bytes());
        h[8..12].copy_from_slice(&total.to_le_bytes());
        h
    };
    if data.is_empty() {
        // Zero-length messages still need one frame so the receiver can
        // match the stream position.
        comm.isend_parts(dst, tag, &[&header(0)]);
        return 1;
    }
    for (i, chunk) in data.chunks(chunk_bytes).enumerate() {
        comm.isend_parts(dst, tag, &[&header(i as u32), chunk]);
    }
    total as usize
}

/// Receiver-side reassembly buffer for interleaved chunked streams.
#[derive(Debug, Default)]
pub struct Reassembler {
    /// (src, tag, msg_id) -> (received chunks, total)
    partial: HashMap<(u32, Tag, u32), (Vec<Option<Vec<u8>>>, u32)>,
    /// Per-source completion flags for [`recv_all_batched_into`]
    /// (capacity reused across iterations).
    done_scratch: Vec<bool>,
}

/// What one [`recv_all_batched_into`] call spent where: wall-clock
/// seconds blocked in the transport (the honest wait), thread-CPU seconds
/// spent copying/reassembling frames, and the number of frames consumed.
/// The engine charges the first to `Op::Transfer` and the second to
/// `Op::Reassembly` — previously the whole blocking loop was timed as one
/// CPU "transfer" bucket, skewing the op breakdown on slow peers.
#[derive(Clone, Copy, Debug, Default)]
pub struct RecvAllStats {
    pub wait_secs: f64,
    pub reassembly_secs: f64,
    pub frames: u64,
}

/// Collect one complete batched message from **each** of `srcs` on `tag`,
/// consuming frames in *arrival* order — no fixed-rank-order blocking
/// wait: a slow first neighbor no longer stalls ingestion of everyone
/// else's already-arrived frames. Source `srcs[k]`'s completed payload
/// lands in `wires[k]` (cleared, capacity reused), so downstream
/// consumers see wires in deterministic source order regardless of the
/// order the network delivered them.
///
/// Protocol assumption (held by the engine's collective-gated iteration
/// loop): at most one in-flight batched message per source on `tag`.
/// Frames from sources outside `srcs` are reassembled and dropped
/// (debug-asserted — they indicate a stale stream).
pub fn recv_all_batched_into(
    re: &mut Reassembler,
    comm: &mut Communicator,
    srcs: &[u32],
    tag: Tag,
    wires: &mut [Vec<u8>],
) -> RecvAllStats {
    assert_eq!(srcs.len(), wires.len(), "one wire slot per source");
    let mut stats = RecvAllStats::default();
    re.done_scratch.clear();
    re.done_scratch.resize(srcs.len(), false);
    let mut discard: Vec<u8> = Vec::new();
    let mut pending = srcs.len();
    while pending > 0 {
        let (m, waited) = comm.recv_any_timed(tag);
        stats.wait_secs += waited;
        stats.frames += 1;
        let t = crate::util::timing::CpuTimer::start();
        match srcs.iter().position(|&s| s == m.src) {
            Some(k) => {
                if re.feed_into(m.src, m.tag, m.data, &mut wires[k]).is_some() {
                    debug_assert!(!re.done_scratch[k], "second message completed for src {}", m.src);
                    if !re.done_scratch[k] {
                        re.done_scratch[k] = true;
                        pending -= 1;
                    }
                }
            }
            None => {
                debug_assert!(false, "aura frame from unexpected source {}", m.src);
                re.feed_into(m.src, m.tag, m.data, &mut discard);
            }
        }
        stats.reassembly_secs += t.elapsed_secs();
    }
    stats
}

impl Reassembler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Feed one received frame; returns the full payload once complete.
    pub fn feed(&mut self, src: u32, tag: Tag, frame: Vec<u8>) -> Option<(u32, Vec<u8>)> {
        let mut out = Vec::new();
        self.feed_into(src, tag, frame, &mut out).map(|id| (id, out))
    }

    /// Feed one received frame, assembling the completed payload into a
    /// caller-owned buffer (cleared first; capacity reused across
    /// messages). The single-chunk common case copies the frame body
    /// straight into `out` without touching the partial-stream map.
    pub fn feed_into(
        &mut self,
        src: u32,
        tag: Tag,
        frame: Vec<u8>,
        out: &mut Vec<u8>,
    ) -> Option<u32> {
        assert!(frame.len() >= FRAME_HEADER, "short chunk frame");
        let msg_id = u32::from_le_bytes(frame[0..4].try_into().unwrap());
        let chunk = u32::from_le_bytes(frame[4..8].try_into().unwrap());
        let total = u32::from_le_bytes(frame[8..12].try_into().unwrap());
        if total == 1 {
            debug_assert_eq!(chunk, 0);
            out.clear();
            out.extend_from_slice(&frame[FRAME_HEADER..]);
            return Some(msg_id);
        }
        let key = (src, tag, msg_id);
        let entry = self
            .partial
            .entry(key)
            .or_insert_with(|| (vec![None; total as usize], total));
        assert_eq!(entry.1, total, "inconsistent chunk totals");
        assert!(entry.0[chunk as usize].is_none(), "duplicate chunk");
        // Move the frame in whole (body offset recorded implicitly by the
        // fixed header size) — no per-chunk copy until assembly.
        entry.0[chunk as usize] = Some(frame);
        if entry.0.iter().all(|c| c.is_some()) {
            let (chunks, _) = self.partial.remove(&key).unwrap();
            out.clear();
            for c in chunks {
                out.extend_from_slice(&c.unwrap()[FRAME_HEADER..]);
            }
            Some(msg_id)
        } else {
            None
        }
    }

    /// Receive a complete batched message from `src` on `tag` (blocking).
    pub fn recv_batched(&mut self, comm: &mut Communicator, src: u32, tag: Tag) -> (u32, Vec<u8>) {
        let mut out = Vec::new();
        let id = self.recv_batched_into(comm, src, tag, &mut out);
        (id, out)
    }

    /// [`Reassembler::recv_batched`] into a caller-owned buffer, for the
    /// allocation-free aura receive path.
    pub fn recv_batched_into(
        &mut self,
        comm: &mut Communicator,
        src: u32,
        tag: Tag,
        out: &mut Vec<u8>,
    ) -> u32 {
        loop {
            let m = comm.recv(Some(src), Some(tag));
            if let Some(id) = self.feed_into(m.src, m.tag, m.data, out) {
                return id;
            }
        }
    }

    /// Number of incomplete streams (diagnostics).
    pub fn pending(&self) -> usize {
        self.partial.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::mpi::MpiWorld;
    use crate::comm::network::NetworkModel;
    use crate::util::Rng;
    use std::sync::Arc;

    #[test]
    fn single_chunk_round_trip() {
        let world = MpiWorld::new(2, NetworkModel::ideal());
        let mut tx = world.communicator(0);
        let mut rx = world.communicator(1);
        let n = send_batched(&mut tx, 1, 7, 1, b"hello", 1024);
        assert_eq!(n, 1);
        let mut re = Reassembler::new();
        let (id, data) = re.recv_batched(&mut rx, 0, 7);
        assert_eq!(id, 1);
        assert_eq!(data, b"hello");
        assert_eq!(re.pending(), 0);
    }

    #[test]
    fn multi_chunk_round_trip() {
        let world = MpiWorld::new(2, NetworkModel::ideal());
        let mut tx = world.communicator(0);
        let mut rx = world.communicator(1);
        let mut rng = Rng::new(5);
        let data: Vec<u8> = (0..10_000).map(|_| rng.next_u64() as u8).collect();
        let n = send_batched(&mut tx, 1, 7, 42, &data, 1024);
        assert_eq!(n, 10);
        let mut re = Reassembler::new();
        let (id, got) = re.recv_batched(&mut rx, 0, 7);
        assert_eq!(id, 42);
        assert_eq!(got, data);
    }

    #[test]
    fn empty_message_still_frames() {
        let world = MpiWorld::new(2, NetworkModel::ideal());
        let mut tx = world.communicator(0);
        let mut rx = world.communicator(1);
        send_batched(&mut tx, 1, 7, 9, &[], 1024);
        let mut re = Reassembler::new();
        let (id, got) = re.recv_batched(&mut rx, 0, 7);
        assert_eq!(id, 9);
        assert!(got.is_empty());
    }

    #[test]
    fn interleaved_streams_reassemble_independently() {
        let world = MpiWorld::new(3, NetworkModel::ideal());
        let mut a = world.communicator(0);
        let mut b = world.communicator(1);
        let mut rx = world.communicator(2);
        let da = vec![1u8; 3000];
        let db = vec![2u8; 3000];
        send_batched(&mut a, 2, 7, 1, &da, 1000);
        send_batched(&mut b, 2, 7, 1, &db, 1000);
        let mut re = Reassembler::new();
        let mut done = Vec::new();
        while done.len() < 2 {
            let m = rx.recv(None, Some(7));
            if let Some((_, data)) = re.feed(m.src, m.tag, m.data) {
                done.push((m.src, data));
            }
        }
        done.sort_by_key(|(s, _)| *s);
        assert_eq!(done[0].1, da);
        assert_eq!(done[1].1, db);
    }

    #[test]
    fn recv_batched_into_reuses_buffer() {
        let world = MpiWorld::new(2, NetworkModel::ideal());
        let mut tx = world.communicator(0);
        let mut rx = world.communicator(1);
        let mut re = Reassembler::new();
        let mut out = Vec::new();
        for round in 0u32..4 {
            let data = vec![round as u8; 2500];
            send_batched(&mut tx, 1, 7, round, &data, 1000);
            let id = re.recv_batched_into(&mut rx, 0, 7, &mut out);
            assert_eq!(id, round);
            assert_eq!(out, data);
        }
        let cap = out.capacity();
        send_batched(&mut tx, 1, 7, 9, &[1, 2, 3], 1000);
        re.recv_batched_into(&mut rx, 0, 7, &mut out);
        assert_eq!(out, vec![1, 2, 3]);
        assert_eq!(out.capacity(), cap, "steady-state receive must not realloc");
    }

    #[test]
    fn recv_all_collects_every_source_in_any_arrival_order() {
        // Three senders, chunked wires, three adversarial delivery orders
        // (all sends happen before the receiver starts, so the mailbox
        // arrival order IS the send order below). Results must land in
        // source order regardless.
        let payload = |s: u32| -> Vec<u8> { vec![s as u8; 700 * (s as usize + 1)] };
        let orders: [[u32; 3]; 3] = [[1, 2, 3], [3, 2, 1], [2, 3, 1]];
        for order in orders {
            let world = MpiWorld::new(4, NetworkModel::ideal());
            let mut rx = world.communicator(0);
            for &s in &order {
                let mut tx = world.communicator(s);
                send_batched(&mut tx, 0, 7, 11, &payload(s), 256);
            }
            let mut re = Reassembler::new();
            let srcs = [1u32, 2, 3];
            let mut wires: Vec<Vec<u8>> = vec![Vec::new(); 3];
            let stats = recv_all_batched_into(&mut re, &mut rx, &srcs, 7, &mut wires);
            for (k, &s) in srcs.iter().enumerate() {
                assert_eq!(wires[k], payload(s), "order {order:?}, src {s}");
            }
            // Frames: ceil(700(s+1)/256) per source.
            let expect_frames: u64 = (1..=3u64).map(|s| (700 * (s + 1)).div_ceil(256)).sum();
            assert_eq!(stats.frames, expect_frames);
            assert_eq!(re.pending(), 0);
        }
    }

    #[test]
    fn recv_all_overlaps_blocking_with_late_senders() {
        // The receiver starts before the last sender has sent anything;
        // it must ingest the early wires and block only for the rest.
        let world = MpiWorld::new(3, NetworkModel::ideal());
        let mut early = world.communicator(1);
        let data1 = vec![1u8; 5000];
        send_batched(&mut early, 0, 7, 3, &data1, 1024);
        let world2 = Arc::clone(&world);
        let late = std::thread::spawn(move || {
            let mut tx = world2.communicator(2);
            std::thread::sleep(std::time::Duration::from_millis(10));
            send_batched(&mut tx, 0, 7, 3, &[42u8; 100], 1024);
        });
        let mut rx = world.communicator(0);
        let mut re = Reassembler::new();
        let mut wires: Vec<Vec<u8>> = vec![Vec::new(); 2];
        let stats = recv_all_batched_into(&mut re, &mut rx, &[1, 2], 7, &mut wires);
        late.join().unwrap();
        assert_eq!(wires[0], data1);
        assert_eq!(wires[1], vec![42u8; 100]);
        assert!(stats.wait_secs > 0.0, "blocked wait on the late sender must be visible");
    }

    #[test]
    fn world_handle_is_shareable() {
        let world = MpiWorld::new(2, NetworkModel::ideal());
        let w2 = Arc::clone(&world);
        assert_eq!(w2.size(), 2);
    }
}
