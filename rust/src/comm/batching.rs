//! Large-message batching (§2.4.3: "we transmit large messages in smaller
//! batches to reduce the memory needed for transmission buffers,
//! compression, and serialization") over the pooled-frame transport.
//!
//! A payload larger than the configured chunk size is split into numbered
//! chunks; the receiver reassembles them. Framing: `[msg_id u32]
//! [chunk u32][total u32][bytes...]`, all little-endian.
//!
//! # Copy discipline
//!
//! The send side has two entry points. [`send_batched`] borrows the wire
//! (`&[u8]`) and stages header + chunk into pooled frames — one copy per
//! chunk, no allocation. [`send_batched_framed`] is the zero-copy fast
//! path the aura exchange uses: the caller encodes the wire into its
//! buffer **after a reserved [`FRAME_HEADER`]-byte gap**, the header is
//! written into the gap in place, and the whole buffer is published as a
//! pooled [`Frame`] — the bytes the encoder wrote are the bytes the
//! decoder reads, with the pool lending the caller a recycled replacement
//! buffer for the next iteration.
//!
//! The receive side mirrors this with [`WireSlot`]: a message that fit a
//! single frame is handed over as [`WireSlot::Direct`] — the frame
//! itself, body borrowed in place, **zero receive-side copies** — while a
//! multi-chunk message is staged once into a pooled aligned buffer shared
//! with the decode [`ViewPool`] ([`WireSlot::Staged`]; the per-frame
//! copy is metered in [`RecvAllStats::copied_bytes`]). Either way the
//! steady state allocates nothing.

use super::mpi::{Communicator, Frame, Tag};
use crate::io::buffer::AlignedBuf;
use crate::io::codec::WirePayload;
use crate::io::ta_io::ViewPool;
use std::collections::HashMap;

/// Default chunk size (1 MiB) — bounds peak transmission-buffer memory.
pub const DEFAULT_CHUNK_BYTES: usize = 1 << 20;

/// Bytes of the per-chunk framing header (`msg_id`, `chunk`, `total`).
/// [`send_batched_framed`] callers reserve this many bytes at the front
/// of their wire buffer so single-chunk messages publish without a copy.
pub const FRAME_HEADER: usize = 12;

fn header(msg_id: u32, chunk: u32, total: u32) -> [u8; FRAME_HEADER] {
    let mut h = [0u8; FRAME_HEADER];
    h[0..4].copy_from_slice(&msg_id.to_le_bytes());
    h[4..8].copy_from_slice(&chunk.to_le_bytes());
    h[8..12].copy_from_slice(&total.to_le_bytes());
    h
}

fn parse_header(frame: &[u8]) -> (u32, u32, u32) {
    assert!(frame.len() >= FRAME_HEADER, "short chunk frame");
    (
        u32::from_le_bytes(frame[0..4].try_into().unwrap()),
        u32::from_le_bytes(frame[4..8].try_into().unwrap()),
        u32::from_le_bytes(frame[8..12].try_into().unwrap()),
    )
}

/// Sender side: split `data` into frames and send them to `dst` on `tag`.
/// `msg_id` must be unique per (sender, receiver, tag) stream position —
/// the engine uses its iteration counter.
///
/// The caller keeps ownership of `data`; each frame is staged (header +
/// chunk slice) into a pooled transport frame — one copy per chunk, zero
/// allocation. When the caller can reserve a [`FRAME_HEADER`] gap in its
/// buffer, [`send_batched_framed`] skips even that copy for single-chunk
/// messages.
pub fn send_batched(
    comm: &mut Communicator,
    dst: u32,
    tag: Tag,
    msg_id: u32,
    data: &[u8],
    chunk_bytes: usize,
) -> usize {
    let chunk_bytes = chunk_bytes.max(1);
    let total = data.len().div_ceil(chunk_bytes).max(1) as u32;
    if data.is_empty() {
        // Zero-length messages still need one frame so the receiver can
        // match the stream position.
        comm.isend_parts(dst, tag, &[&header(msg_id, 0, 1)]);
        return 1;
    }
    for (i, chunk) in data.chunks(chunk_bytes).enumerate() {
        comm.isend_parts(dst, tag, &[&header(msg_id, i as u32, total), chunk]);
    }
    total as usize
}

/// The zero-copy batched send: `wire` holds `[FRAME_HEADER reserved gap]
/// [message bytes]` (the gap is what [`Codec::encode_rm_overlapped`]
/// leaves when asked for one). If the message fits one chunk, the header
/// is written into the gap and the **whole buffer is published in place**
/// as a pooled frame — no copy anywhere between the encoder's write and
/// the decoder's read — while `wire` is swapped for a recycled buffer
/// from the world's frame pool, keeping the caller's capacity cycling.
/// Larger messages fall back to per-chunk staging like [`send_batched`]
/// (the chunk split is itself the §2.4.3 memory cap) and leave `wire`
/// with the caller. Returns the number of frames sent.
///
/// [`Codec::encode_rm_overlapped`]: crate::io::codec::Codec::encode_rm_overlapped
pub fn send_batched_framed(
    comm: &mut Communicator,
    dst: u32,
    tag: Tag,
    msg_id: u32,
    wire: &mut Vec<u8>,
    chunk_bytes: usize,
) -> usize {
    assert!(wire.len() >= FRAME_HEADER, "framed wire is missing its header gap");
    let chunk_bytes = chunk_bytes.max(1);
    let body_len = wire.len() - FRAME_HEADER;
    if body_len <= chunk_bytes {
        wire[..FRAME_HEADER].copy_from_slice(&header(msg_id, 0, 1));
        let pool = comm.frame_pool().clone();
        let buf = std::mem::replace(wire, pool.take_vec());
        comm.isend_frame(dst, tag, pool.seal(buf));
        return 1;
    }
    let total = body_len.div_ceil(chunk_bytes) as u32;
    for (i, chunk) in wire[FRAME_HEADER..].chunks(chunk_bytes).enumerate() {
        comm.isend_parts(dst, tag, &[&header(msg_id, i as u32, total), chunk]);
    }
    total as usize
}

/// One source's completed wire on the receive side: either the published
/// frame itself (single-chunk — the decode reads the sender's bytes in
/// place) or a pooled staging buffer the chunks were assembled into.
#[derive(Debug, Default)]
pub enum WireSlot {
    #[default]
    Empty,
    /// A complete single-frame message; the wire body follows the
    /// [`FRAME_HEADER`] in the frame the sender published.
    Direct(Frame),
    /// A multi-chunk message assembled into a buffer from the decode
    /// pool ([`ViewPool`]); recycle it back with
    /// [`WireSlot::recycle_into`].
    Staged(AlignedBuf),
}

impl WireSlot {
    /// The wire message bytes (codec envelope + payload).
    pub fn as_wire(&self) -> &[u8] {
        match self {
            WireSlot::Empty => &[],
            WireSlot::Direct(f) => &f[FRAME_HEADER..],
            WireSlot::Staged(b) => b.as_slice(),
        }
    }

    /// Release the backing storage: a staged buffer returns to `pool`, a
    /// direct frame recycles into its transport pool on drop.
    pub fn recycle_into(self, pool: &mut ViewPool) {
        if let WireSlot::Staged(buf) = self {
            pool.put_buf(buf);
        }
    }
}

impl AsRef<[u8]> for WireSlot {
    fn as_ref(&self) -> &[u8] {
        self.as_wire()
    }
}

impl WirePayload for WireSlot {
    fn wire(&self) -> &[u8] {
        self.as_wire()
    }

    fn recycle(self, pool: &mut ViewPool) {
        self.recycle_into(pool);
    }
}

/// Receiver-side reassembly state for interleaved chunked streams.
/// Chunks are held as received frames (frame-granular, no copy) until a
/// stream completes; only then is the payload assembled once into a
/// pooled buffer. All scratch recycles across messages.
#[derive(Debug, Default)]
pub struct Reassembler {
    /// (src, tag, msg_id) -> (received chunk frames, total)
    partial: HashMap<(u32, Tag, u32), (Vec<Option<Frame>>, u32)>,
    /// Freelist of chunk-slot vectors (capacity reused across streams).
    chunk_scratch: Vec<Vec<Option<Frame>>>,
    /// Per-source completion flags for [`recv_all_batched_streaming`]
    /// (capacity reused across iterations).
    done_scratch: Vec<bool>,
}

/// What one receive-all call spent where: wall-clock seconds blocked in
/// the transport (the honest wait), thread-CPU seconds spent parsing and
/// assembling frames, bytes copied by multi-chunk staging (`0` when every
/// message fit a single frame — the zero-copy fast path), and the number
/// of frames consumed. The engine charges `wait_secs` to `Op::Transfer`
/// and `reassembly_secs` to `Op::Reassembly`, and counts `copied_bytes`
/// under `Counter::BytesReassembled`.
#[derive(Clone, Copy, Debug, Default)]
pub struct RecvAllStats {
    pub wait_secs: f64,
    pub reassembly_secs: f64,
    pub copied_bytes: u64,
    pub frames: u64,
}

/// Collect one complete batched message from **each** of `srcs` on `tag`,
/// consuming frames in *arrival* order — no fixed-rank-order blocking
/// wait: a slow first neighbor never stalls ingestion of everyone else's
/// already-arrived frames. The moment source `srcs[k]`'s message
/// completes, `complete(k, slot)` runs **on the calling thread** with the
/// finished [`WireSlot`] — this is the producer half of the streaming
/// ingest: feed the slot to decode workers
/// ([`Codec::decode_pooled_streamed`]) and the first source's decode
/// overlaps the last source's network wait. Multi-chunk staging buffers
/// come from `staging` (the decode pool, closing the recycle loop).
///
/// Protocol assumption (held by the engine's collective-gated iteration
/// loop): at most one in-flight batched message per source on `tag`.
/// Frames from sources outside `srcs` are reassembled and dropped
/// (debug-asserted — they indicate a stale stream).
///
/// [`Codec::decode_pooled_streamed`]: crate::io::codec::Codec::decode_pooled_streamed
pub fn recv_all_batched_streaming(
    re: &mut Reassembler,
    comm: &mut Communicator,
    srcs: &[u32],
    tag: Tag,
    staging: &mut ViewPool,
    mut complete: impl FnMut(usize, WireSlot),
) -> RecvAllStats {
    let mut stats = RecvAllStats::default();
    re.done_scratch.clear();
    re.done_scratch.resize(srcs.len(), false);
    let mut pending = srcs.len();
    while pending > 0 {
        let (m, waited) = comm.recv_any_timed(tag);
        stats.wait_secs += waited;
        stats.frames += 1;
        let t = crate::util::timing::CpuTimer::start();
        let fed = match srcs.iter().position(|&s| s == m.src) {
            Some(k) => re.feed_frame(m.src, m.tag, m.data, staging).map(|(_, slot)| (k, slot)),
            None => {
                debug_assert!(false, "aura frame from unexpected source {}", m.src);
                // Reassemble and drop so the stale stream can't poison
                // the partial map.
                if let Some((_, slot)) = re.feed_frame(m.src, m.tag, m.data, staging) {
                    slot.recycle_into(staging);
                }
                None
            }
        };
        if let Some((_, slot)) = &fed {
            if let WireSlot::Staged(buf) = slot {
                stats.copied_bytes += buf.len() as u64;
            }
        }
        stats.reassembly_secs += t.elapsed_secs();
        if let Some((k, slot)) = fed {
            debug_assert!(!re.done_scratch[k], "second message completed for src {}", m.src);
            if !re.done_scratch[k] {
                re.done_scratch[k] = true;
                pending -= 1;
                complete(k, slot);
            }
        }
    }
    stats
}

/// [`recv_all_batched_streaming`] without the streaming consumer: every
/// completed wire parks in its source's slot (`wires[k]` for `srcs[k]`,
/// deterministic source order regardless of delivery order). Kept for
/// callers that genuinely need all wires before acting; the engine uses
/// the streaming form.
pub fn recv_all_batched_into(
    re: &mut Reassembler,
    comm: &mut Communicator,
    srcs: &[u32],
    tag: Tag,
    wires: &mut [WireSlot],
    staging: &mut ViewPool,
) -> RecvAllStats {
    assert_eq!(srcs.len(), wires.len(), "one wire slot per source");
    recv_all_batched_streaming(re, comm, srcs, tag, staging, |k, slot| wires[k] = slot)
}

impl Reassembler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Park one chunk frame; returns the stream's chunk frames once all
    /// have arrived.
    fn stash_chunk(
        &mut self,
        src: u32,
        tag: Tag,
        msg_id: u32,
        chunk: u32,
        total: u32,
        frame: Frame,
    ) -> Option<Vec<Option<Frame>>> {
        let Reassembler { partial, chunk_scratch, .. } = self;
        let key = (src, tag, msg_id);
        let entry = partial.entry(key).or_insert_with(|| {
            let mut v = chunk_scratch.pop().unwrap_or_default();
            v.clear();
            v.resize_with(total as usize, || None);
            (v, total)
        });
        assert_eq!(entry.1, total, "inconsistent chunk totals");
        assert!(entry.0[chunk as usize].is_none(), "duplicate chunk");
        // The frame is parked whole (body offset fixed by the header
        // size) — chunks stay in the sender's published buffers until
        // the one assembly pass.
        entry.0[chunk as usize] = Some(frame);
        if entry.0.iter().all(|c| c.is_some()) {
            Some(partial.remove(&key).unwrap().0)
        } else {
            None
        }
    }

    fn recycle_chunks(&mut self, mut chunks: Vec<Option<Frame>>) {
        chunks.clear();
        self.chunk_scratch.push(chunks);
    }

    /// Feed one received frame. A single-chunk message completes with
    /// **zero copies** — the returned [`WireSlot::Direct`] *is* the
    /// published frame. A multi-chunk stream completes by assembling the
    /// chunk bodies once into a buffer from `staging`
    /// ([`WireSlot::Staged`]); the spent chunk frames recycle into the
    /// transport pool as they drop.
    pub fn feed_frame(
        &mut self,
        src: u32,
        tag: Tag,
        frame: Frame,
        staging: &mut ViewPool,
    ) -> Option<(u32, WireSlot)> {
        let (msg_id, chunk, total) = parse_header(&frame);
        if total == 1 {
            debug_assert_eq!(chunk, 0);
            return Some((msg_id, WireSlot::Direct(frame)));
        }
        let mut chunks = self.stash_chunk(src, tag, msg_id, chunk, total, frame)?;
        let mut buf = staging.take_buf();
        buf.clear();
        let bytes: usize = chunks.iter().map(|c| c.as_ref().unwrap().len() - FRAME_HEADER).sum();
        buf.reserve(bytes);
        for c in chunks.iter_mut() {
            let f = c.take().unwrap();
            buf.extend_from_slice(&f[FRAME_HEADER..]);
        }
        self.recycle_chunks(chunks);
        Some((msg_id, WireSlot::Staged(buf)))
    }

    /// Feed one received frame; returns the full payload once complete
    /// (copying convenience wrapper around the frame-granular path).
    pub fn feed(&mut self, src: u32, tag: Tag, frame: Frame) -> Option<(u32, Vec<u8>)> {
        let mut out = Vec::new();
        self.feed_into(src, tag, frame, &mut out).map(|id| (id, out))
    }

    /// Feed one received frame, assembling the completed payload into a
    /// caller-owned buffer (cleared first; capacity reused across
    /// messages). This is the *copying* legacy surface — the streaming
    /// receive path hands out [`WireSlot`]s via
    /// [`Reassembler::feed_frame`] instead and copies nothing for
    /// single-chunk messages.
    pub fn feed_into(
        &mut self,
        src: u32,
        tag: Tag,
        frame: Frame,
        out: &mut Vec<u8>,
    ) -> Option<u32> {
        let (msg_id, chunk, total) = parse_header(&frame);
        if total == 1 {
            debug_assert_eq!(chunk, 0);
            out.clear();
            out.extend_from_slice(&frame[FRAME_HEADER..]);
            return Some(msg_id);
        }
        let mut chunks = self.stash_chunk(src, tag, msg_id, chunk, total, frame)?;
        out.clear();
        for c in chunks.iter_mut() {
            let f = c.take().unwrap();
            out.extend_from_slice(&f[FRAME_HEADER..]);
        }
        self.recycle_chunks(chunks);
        Some(msg_id)
    }

    /// Receive a complete batched message from `src` on `tag` (blocking).
    pub fn recv_batched(&mut self, comm: &mut Communicator, src: u32, tag: Tag) -> (u32, Vec<u8>) {
        let mut out = Vec::new();
        let id = self.recv_batched_into(comm, src, tag, &mut out);
        (id, out)
    }

    /// [`Reassembler::recv_batched`] into a caller-owned buffer, for
    /// fixed-source receive loops.
    pub fn recv_batched_into(
        &mut self,
        comm: &mut Communicator,
        src: u32,
        tag: Tag,
        out: &mut Vec<u8>,
    ) -> u32 {
        loop {
            let m = comm.recv(Some(src), Some(tag));
            if let Some(id) = self.feed_into(m.src, m.tag, m.data, out) {
                return id;
            }
        }
    }

    /// Number of incomplete streams (diagnostics).
    pub fn pending(&self) -> usize {
        self.partial.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::mpi::MpiWorld;
    use crate::comm::network::NetworkModel;
    use crate::util::Rng;
    use std::sync::Arc;

    fn empty_slots(n: usize) -> Vec<WireSlot> {
        std::iter::repeat_with(WireSlot::default).take(n).collect()
    }

    #[test]
    fn single_chunk_round_trip() {
        let world = MpiWorld::new(2, NetworkModel::ideal());
        let mut tx = world.communicator(0);
        let mut rx = world.communicator(1);
        let n = send_batched(&mut tx, 1, 7, 1, b"hello", 1024);
        assert_eq!(n, 1);
        let mut re = Reassembler::new();
        let (id, data) = re.recv_batched(&mut rx, 0, 7);
        assert_eq!(id, 1);
        assert_eq!(data, b"hello");
        assert_eq!(re.pending(), 0);
    }

    #[test]
    fn multi_chunk_round_trip() {
        let world = MpiWorld::new(2, NetworkModel::ideal());
        let mut tx = world.communicator(0);
        let mut rx = world.communicator(1);
        let mut rng = Rng::new(5);
        let data: Vec<u8> = (0..10_000).map(|_| rng.next_u64() as u8).collect();
        let n = send_batched(&mut tx, 1, 7, 42, &data, 1024);
        assert_eq!(n, 10);
        let mut re = Reassembler::new();
        let (id, got) = re.recv_batched(&mut rx, 0, 7);
        assert_eq!(id, 42);
        assert_eq!(got, data);
    }

    #[test]
    fn empty_message_still_frames() {
        let world = MpiWorld::new(2, NetworkModel::ideal());
        let mut tx = world.communicator(0);
        let mut rx = world.communicator(1);
        send_batched(&mut tx, 1, 7, 9, &[], 1024);
        let mut re = Reassembler::new();
        let (id, got) = re.recv_batched(&mut rx, 0, 7);
        assert_eq!(id, 9);
        assert!(got.is_empty());
    }

    #[test]
    fn framed_send_publishes_single_chunk_without_copy() {
        let world = MpiWorld::new(2, NetworkModel::ideal());
        let mut tx = world.communicator(0);
        let mut rx = world.communicator(1);
        let mut wire = vec![0u8; FRAME_HEADER];
        wire.extend_from_slice(b"framed body");
        let body_ptr = wire[FRAME_HEADER..].as_ptr();
        let n = send_batched_framed(&mut tx, 1, 7, 3, &mut wire, 1024);
        assert_eq!(n, 1);
        // The caller's buffer was swapped for a pool lease.
        assert!(wire.is_empty());
        let (m, _) = rx.recv_any_timed(7);
        let mut re = Reassembler::new();
        let mut staging = ViewPool::new();
        let (id, slot) = re.feed_frame(m.src, m.tag, m.data, &mut staging).unwrap();
        assert_eq!(id, 3);
        assert_eq!(slot.as_wire(), b"framed body");
        // Zero-copy end to end: the decoder-visible bytes live at the
        // very address the sender wrote them to.
        assert_eq!(slot.as_wire().as_ptr(), body_ptr);
    }

    #[test]
    fn framed_send_chunks_large_wires_and_keeps_the_buffer() {
        let world = MpiWorld::new(2, NetworkModel::ideal());
        let mut tx = world.communicator(0);
        let mut rx = world.communicator(1);
        let body: Vec<u8> = (0..3000u32).map(|i| i as u8).collect();
        let mut wire = vec![0u8; FRAME_HEADER];
        wire.extend_from_slice(&body);
        let n = send_batched_framed(&mut tx, 1, 7, 8, &mut wire, 1000);
        assert_eq!(n, 3);
        assert_eq!(wire.len(), FRAME_HEADER + body.len(), "multi-chunk send keeps the wire");
        let mut re = Reassembler::new();
        let mut staging = ViewPool::new();
        let mut got = None;
        while got.is_none() {
            let (m, _) = rx.recv_any_timed(7);
            got = re.feed_frame(m.src, m.tag, m.data, &mut staging);
        }
        let (id, slot) = got.unwrap();
        assert_eq!(id, 8);
        assert_eq!(slot.as_wire(), &body[..]);
        assert!(matches!(slot, WireSlot::Staged(_)));
        slot.recycle_into(&mut staging);
        assert!(staging.approx_bytes() > 0, "staging buffer must recycle");
    }

    #[test]
    fn interleaved_streams_reassemble_independently() {
        let world = MpiWorld::new(3, NetworkModel::ideal());
        let mut a = world.communicator(0);
        let mut b = world.communicator(1);
        let mut rx = world.communicator(2);
        let da = vec![1u8; 3000];
        let db = vec![2u8; 3000];
        send_batched(&mut a, 2, 7, 1, &da, 1000);
        send_batched(&mut b, 2, 7, 1, &db, 1000);
        let mut re = Reassembler::new();
        let mut done = Vec::new();
        while done.len() < 2 {
            let m = rx.recv(None, Some(7));
            let src = m.src;
            if let Some((_, data)) = re.feed(src, m.tag, m.data) {
                done.push((src, data));
            }
        }
        done.sort_by_key(|(s, _)| *s);
        assert_eq!(done[0].1, da);
        assert_eq!(done[1].1, db);
    }

    #[test]
    fn recv_batched_into_reuses_buffer() {
        let world = MpiWorld::new(2, NetworkModel::ideal());
        let mut tx = world.communicator(0);
        let mut rx = world.communicator(1);
        let mut re = Reassembler::new();
        let mut out = Vec::new();
        for round in 0u32..4 {
            let data = vec![round as u8; 2500];
            send_batched(&mut tx, 1, 7, round, &data, 1000);
            let id = re.recv_batched_into(&mut rx, 0, 7, &mut out);
            assert_eq!(id, round);
            assert_eq!(out, data);
        }
        let cap = out.capacity();
        send_batched(&mut tx, 1, 7, 9, &[1, 2, 3], 1000);
        re.recv_batched_into(&mut rx, 0, 7, &mut out);
        assert_eq!(out, vec![1, 2, 3]);
        assert_eq!(out.capacity(), cap, "steady-state receive must not realloc");
    }

    #[test]
    fn recv_all_collects_every_source_in_any_arrival_order() {
        // Three senders, chunked wires, three adversarial delivery orders
        // (all sends happen before the receiver starts, so the mailbox
        // arrival order IS the send order below). Results must land in
        // source order regardless.
        let payload = |s: u32| -> Vec<u8> { vec![s as u8; 700 * (s as usize + 1)] };
        let orders: [[u32; 3]; 3] = [[1, 2, 3], [3, 2, 1], [2, 3, 1]];
        for order in orders {
            let world = MpiWorld::new(4, NetworkModel::ideal());
            let mut rx = world.communicator(0);
            for &s in &order {
                let mut tx = world.communicator(s);
                send_batched(&mut tx, 0, 7, 11, &payload(s), 256);
            }
            let mut re = Reassembler::new();
            let srcs = [1u32, 2, 3];
            let mut staging = ViewPool::new();
            let mut wires = empty_slots(3);
            let stats =
                recv_all_batched_into(&mut re, &mut rx, &srcs, 7, &mut wires, &mut staging);
            for (k, &s) in srcs.iter().enumerate() {
                assert_eq!(wires[k].as_wire(), &payload(s)[..], "order {order:?}, src {s}");
            }
            // Frames: ceil(700(s+1)/256) per source; every chunked stream
            // is staged, so the copied bytes are the full payloads.
            let expect_frames: u64 = (1..=3u64).map(|s| (700 * (s + 1)).div_ceil(256)).sum();
            assert_eq!(stats.frames, expect_frames);
            let expect_bytes: u64 = (1..=3u64).map(|s| 700 * (s + 1)).sum();
            assert_eq!(stats.copied_bytes, expect_bytes);
            assert_eq!(re.pending(), 0);
        }
    }

    #[test]
    fn recv_all_single_frame_messages_copy_nothing() {
        let world = MpiWorld::new(3, NetworkModel::ideal());
        let mut rx = world.communicator(0);
        for s in [1u32, 2] {
            let mut tx = world.communicator(s);
            send_batched(&mut tx, 0, 7, 5, &[s as u8; 100], 1024);
        }
        let mut re = Reassembler::new();
        let mut staging = ViewPool::new();
        let mut wires = empty_slots(2);
        let stats = recv_all_batched_into(&mut re, &mut rx, &[1, 2], 7, &mut wires, &mut staging);
        assert_eq!(stats.copied_bytes, 0, "single-frame wires must be handed over in place");
        for (k, s) in [1u8, 2].iter().enumerate() {
            assert!(matches!(wires[k], WireSlot::Direct(_)));
            assert_eq!(wires[k].as_wire(), &vec![*s; 100][..]);
        }
        // Dropping the slots returns the frames to the transport pool.
        wires.clear();
        assert_eq!(world.frame_pool().stats().outstanding, 0);
    }

    #[test]
    fn streaming_receive_completes_in_arrival_order() {
        // Sources 2 and 3 send before 1; the streaming consumer must see
        // their completions first even though slot order is source order.
        let world = MpiWorld::new(4, NetworkModel::ideal());
        let mut rx = world.communicator(0);
        for &s in &[3u32, 2, 1] {
            let mut tx = world.communicator(s);
            send_batched(&mut tx, 0, 7, 1, &[s as u8; 50], 1024);
        }
        let mut re = Reassembler::new();
        let mut staging = ViewPool::new();
        let mut seen = Vec::new();
        recv_all_batched_streaming(&mut re, &mut rx, &[1, 2, 3], 7, &mut staging, |k, slot| {
            assert_eq!(slot.as_wire()[0] as usize, k + 1, "slot index must map to source");
            seen.push(k);
        });
        assert_eq!(seen, vec![2, 1, 0], "completions must stream in arrival order");
    }

    #[test]
    fn recv_all_overlaps_blocking_with_late_senders() {
        // The receiver starts before the last sender has sent anything;
        // it must ingest the early wires and block only for the rest.
        // The late send is gated on a rendezvous the receiver fires just
        // before entering the receive loop, so the blocked wait cannot be
        // raced away by a descheduled receiver (the mpi.rs
        // recv_any_timed test's handshake pattern).
        const RDV: Tag = 99;
        let world = MpiWorld::new(3, NetworkModel::ideal());
        let mut early = world.communicator(1);
        let data1 = vec![1u8; 5000];
        send_batched(&mut early, 0, 7, 3, &data1, 1024);
        let world2 = Arc::clone(&world);
        let late = std::thread::spawn(move || {
            let mut tx = world2.communicator(2);
            tx.recv(Some(0), Some(RDV));
            std::thread::sleep(std::time::Duration::from_millis(20));
            send_batched(&mut tx, 0, 7, 3, &[42u8; 100], 1024);
        });
        let mut rx = world.communicator(0);
        let mut re = Reassembler::new();
        let mut staging = ViewPool::new();
        let mut wires = empty_slots(2);
        rx.isend(2, RDV, vec![0]);
        let stats = recv_all_batched_into(&mut re, &mut rx, &[1, 2], 7, &mut wires, &mut staging);
        late.join().unwrap();
        assert_eq!(wires[0].as_wire(), &data1[..]);
        assert_eq!(wires[1].as_wire(), &[42u8; 100][..]);
        assert!(stats.wait_secs > 0.0, "blocked wait on the late sender must be visible");
    }

    #[test]
    fn reassembler_scratch_recycles_across_streams() {
        let world = MpiWorld::new(2, NetworkModel::ideal());
        let mut tx = world.communicator(0);
        let mut rx = world.communicator(1);
        let mut re = Reassembler::new();
        let mut staging = ViewPool::new();
        let data = vec![9u8; 4000];
        for round in 0u32..6 {
            send_batched(&mut tx, 1, 7, round, &data, 1000);
            let mut got = None;
            while got.is_none() {
                let (m, _) = rx.recv_any_timed(7);
                got = re.feed_frame(m.src, m.tag, m.data, &mut staging);
            }
            let (id, slot) = got.unwrap();
            assert_eq!(id, round);
            assert_eq!(slot.as_wire(), &data[..]);
            slot.recycle_into(&mut staging);
        }
        assert_eq!(re.pending(), 0);
        // The chunk-slot scratch and every transport frame recycled.
        assert_eq!(world.frame_pool().stats().outstanding, 0);
    }

    #[test]
    fn world_handle_is_shareable() {
        let world = MpiWorld::new(2, NetworkModel::ideal());
        let w2 = Arc::clone(&world);
        assert_eq!(w2.size(), 2);
    }
}
