//! The per-rank simulation: state and the iteration loop (Fig. 1).

use super::behavior::{self, BehaviorCtx};
use super::init::InitCtx;
use super::model::Model;
use super::pool::ThreadPool;
use super::world::{AuraStore, World};
use crate::balance::{diffusive, rcb, replan, weights};
use super::checkpoint;
use crate::comm::batching::{
    recv_all_batched_reliable, recv_all_batched_streaming, send_batched_framed, Reassembler,
    RetryConfig, WireSlot, FRAME_HEADER,
};
use crate::comm::mpi::{tags, CommError, Communicator};
use crate::config::{BalanceMethod, SimConfig};
use crate::core::agent::Behavior;
use crate::core::ids::LocalId;
use crate::core::resource_manager::ResourceManager;
use crate::io::codec::{AuraDecodeJob, AuraEncodeJob, Codec, Decoded};
use crate::io::ta_io::ViewPool;
use crate::io::Compression;
use crate::metrics::{Counter, Op, RankMetrics};
use crate::runtime::mechanics::{native_mechanics_into, GatherSlot, MechanicsBatch, AOT_K, AOT_N};
use crate::runtime::service::MechanicsHandle;
use crate::runtime::MechanicsParams;
use crate::space::{NeighborSearchGrid, NsgEntry, PartitionGrid};
use crate::util::{Rng, Timer, Vec3};
use crate::vis::insitu::{color_of_kind, render_agents, Image};
use crate::vis::provider::{PartitionGridOverlay, VisualizationProvider};

/// Mechanics backend held by a rank: inline native math, or the shared
/// PJRT service thread.
pub enum MechBackend {
    Native,
    Service(MechanicsHandle),
}

impl MechBackend {
    /// Compute displacements into a caller-owned buffer (the gather
    /// slot's reused `disp` vector — ROADMAP "displacement out-buffers"):
    /// the native path writes in place, the service path refills the
    /// buffer from its reply.
    fn compute_into(&self, batch: &MechanicsBatch, p: MechanicsParams, out: &mut Vec<Vec3>) {
        match self {
            MechBackend::Native => native_mechanics_into(batch, p, out),
            MechBackend::Service(h) => h.compute_into(batch, p, out),
        }
    }
}

/// Result returned by each rank thread.
pub struct RankOutcome {
    pub metrics: RankMetrics,
    /// Per-iteration rank-local stats (model-defined).
    pub stats_history: Vec<Vec<f64>>,
    pub final_agents: u64,
    /// Composited frames (rank 0 only).
    pub frames: Vec<Image>,
    /// Final snapshot of this rank's agents: (position, diameter,
    /// class id). Used by verification/hull post-processing — the
    /// "transmit agent positions to the master rank" step of §3.4.
    pub final_snapshot: Vec<(Vec3, f64, u16)>,
    /// Running CRC over every data-plane send (present when
    /// `SimConfig::stream_audit` is on): the cross-backend determinism
    /// witness — identical runs must produce identical digests on every
    /// transport.
    pub aura_stream_crc: Option<u32>,
    /// Total data-plane bytes this rank handed to the transport.
    pub wire_bytes_sent: u64,
}

/// One rank's simulation state.
pub struct RankSim<M: Model> {
    pub rank: u32,
    cfg: SimConfig,
    comm: Communicator,
    grid: PartitionGrid,
    nsg: NeighborSearchGrid,
    rm: ResourceManager,
    aura: AuraStore,
    /// NSG entries added for the current aura (cleared each iteration).
    codec: Codec,
    /// Codec for one-shot transfers (migration): delta disabled.
    migration_codec: Codec,
    reassembler: Reassembler,
    pool: ThreadPool,
    rng: Rng,
    pub metrics: RankMetrics,
    model: M,
    mech: MechBackend,
    iteration: u64,
    /// Monotone all-to-all round counter: the call sequence is identical
    /// on every rank, so equal counters pair up the same logical exchange
    /// even when ranks drift apart between barrier-free iterations.
    a2a_round: u32,
    /// Critical-path CPU of pool-parallel regions this iteration.
    pool_cpu_secs: f64,
    last_iteration_secs: f64,
    stats_history: Vec<Vec<f64>>,
    frames: Vec<Image>,
    // --- per-iteration scratch, reused across iterations so the steady
    // --- state allocates nothing (capacity-reuse only):
    /// Snapshot of live local ids (slot order).
    ids_scratch: Vec<LocalId>,
    /// Mechanics gather batches + neighbor scratch, one per AOT_N group.
    gather: Vec<GatherSlot>,
    /// Aura recipients: (neighbor rank, selected agent ids).
    aura_per_dest: Vec<(u32, Vec<LocalId>)>,
    /// Per-destination wire buffers + stats for the parallel aura encode
    /// (aligned with `aura_per_dest`; wire capacity reused).
    aura_jobs: Vec<AuraEncodeJob>,
    /// Per-agent aura target ranks (`ranks_within_into` scratch).
    rank_scratch: Vec<u32>,
    /// Cached neighbor-rank set; invalidated when rebalancing moves boxes.
    neighbors_cache: Vec<u32>,
    neighbors_dirty: bool,
    /// Migration scratch: (destination rank, leaving id) and the
    /// per-destination id buffers. Ids, not agents: leavers are encoded
    /// columnar straight out of the store (behavior tails from the
    /// arena) *before* removal, so no owned copy is ever materialized.
    migration_leaving: Vec<(u32, LocalId)>,
    migration_per_dest: Vec<Vec<LocalId>>,
    /// Recycler for receive buffers + view offset indices: buffers cycle
    /// pool → decode → aura store → pool, so the exchange path allocates
    /// nothing in steady state.
    view_pool: ViewPool,
    /// Per-source streaming-decode slots (decoded views + stats, source
    /// order; wires are decoded as they arrive, never parked).
    aura_rx_jobs: Vec<AuraDecodeJob>,
    /// Decoded messages in source order, handed to the aura store
    /// (capacity reused; drained every iteration).
    aura_decoded: Vec<Decoded>,
    /// Per-source aura-id ranges of the current iteration (feeds the
    /// NSG's Morton-sharded bulk aura fill).
    aura_ranges: Vec<std::ops::Range<u32>>,
    /// Resync-request drain scratch: (peer, tag) pairs.
    resync_scratch: Vec<(u32, u32)>,
    // --- fault-accounting watermarks: the transport/reassembler keep
    // --- cumulative totals; each iteration harvests the delta since the
    // --- previous sample into the rank metrics.
    checksum_secs_seen: f64,
    faults_detected_seen: u64,
    retransmits_seen: u64,
    faults_injected_seen: u64,
    transport_stalls_seen: u64,
    inline_fallbacks_seen: u64,
    a2a_rejects_seen: u64,
    a2a_nacks_seen: u64,
}

impl<M: Model> RankSim<M> {
    /// Build rank state: partition the space, distribute initial agents.
    pub fn new(rank: u32, cfg: SimConfig, comm: Communicator, model: M, mech: MechBackend) -> Self {
        let whole = cfg.whole_space();
        let radius = model.interaction_radius();
        let box_len = radius * cfg.partition_factor;
        let mut grid = PartitionGrid::new(whole, box_len);
        // Initial partition: uniform-weight RCB over the active ranks
        // (identical deterministic result on every rank). `active_ranks`
        // < size starts the run on a rank prefix — the remaining ranks
        // own nothing and idle in the collectives until a rebalance
        // spreads the world onto them (ARCHITECTURE.md "Elasticity").
        for i in 0..grid.num_boxes() {
            grid.set_weight(i, 1.0);
        }
        let init_ranks = match cfg.active_ranks {
            n if n >= 1 && n < comm.size() => n as u32,
            _ => comm.size() as u32,
        };
        let owners = rcb::rcb_partition(&grid, init_ranks);
        grid.set_owners(owners);
        grid.clear_weights();

        let nsg = NeighborSearchGrid::new(whole, radius);
        let rm = ResourceManager::new(rank);

        // Distributed initialization (§2.4.4).
        let mut ctx = InitCtx::new(rank, &grid, cfg.seed);
        model.create_agents(&mut ctx);
        let batch = ctx.into_batch();
        let mut sim = RankSim {
            rank,
            migration_codec: Codec::new(
                cfg.serializer,
                match cfg.compression {
                    Compression::Lz4Delta { .. } => Compression::Lz4,
                    other => other,
                },
            ),
            codec: Codec::new(cfg.serializer, cfg.compression),
            reassembler: Reassembler::new(),
            pool: ThreadPool::new(cfg.mode.threads_per_rank()),
            rng: Rng::stream(cfg.seed, 0xFA57_0000 + rank as u64),
            metrics: RankMetrics::new(),
            model,
            mech,
            iteration: 0,
            a2a_round: 0,
            pool_cpu_secs: 0.0,
            last_iteration_secs: 0.0,
            stats_history: Vec::new(),
            frames: Vec::new(),
            ids_scratch: Vec::new(),
            gather: Vec::new(),
            aura_per_dest: Vec::new(),
            aura_jobs: Vec::new(),
            rank_scratch: Vec::new(),
            neighbors_cache: Vec::new(),
            neighbors_dirty: true,
            migration_leaving: Vec::new(),
            migration_per_dest: Vec::new(),
            view_pool: ViewPool::new(),
            aura_rx_jobs: Vec::new(),
            aura_decoded: Vec::new(),
            aura_ranges: Vec::new(),
            resync_scratch: Vec::new(),
            checksum_secs_seen: 0.0,
            faults_detected_seen: 0,
            retransmits_seen: 0,
            faults_injected_seen: 0,
            transport_stalls_seen: 0,
            inline_fallbacks_seen: 0,
            a2a_rejects_seen: 0,
            a2a_nacks_seen: 0,
            comm,
            grid,
            nsg,
            aura: AuraStore::new(),
            rm,
            cfg,
        };
        // A bounded receive needs the sender side archiving frames for
        // retransmission; chaos installs (tests) flip this on themselves.
        if sim.cfg.recv_timeout_ms > 0 {
            sim.comm.set_reliable(true);
        }
        // Opt-in liveness plane: with a death timeout configured, a
        // persistently silent peer escalates past the retry ladder to
        // `RankDead` and the elastic reshard path (ARCHITECTURE.md
        // "Elasticity").
        if sim.cfg.death_timeout_ms > 0 {
            sim.comm
                .enable_liveness(std::time::Duration::from_millis(sim.cfg.death_timeout_ms));
        }
        // The determinism witness: a running digest over every data-plane
        // send. Backends must agree digest-for-digest on a seeded run.
        if sim.cfg.stream_audit {
            sim.comm.enable_stream_audit();
        }
        for (a, bs) in batch.iter() {
            let id = sim.rm.add_with_behaviors(*a, bs);
            let pos = sim.rm.get(id).unwrap().position;
            sim.nsg.add(NsgEntry::Owned(id), pos);
        }
        sim
    }

    pub fn agent_count(&self) -> usize {
        self.rm.len()
    }

    /// Run the configured number of iterations.
    pub fn run(mut self) -> RankOutcome {
        for _ in 0..self.cfg.iterations {
            // A scripted kill (chaos `kill_at_iteration`) silences this
            // rank from iteration k on. Stop participating entirely —
            // peers see exactly what a crashed rank looks like: its last
            // message was iteration k-1, then nothing, on any tag — but
            // return the outcome normally so the launcher can still join
            // the thread.
            if self
                .comm
                .chaos_plan()
                .and_then(|p| p.kill_at_iteration)
                .is_some_and(|k| self.iteration >= k)
            {
                break;
            }
            self.iterate();
        }
        // A killed rank's agents are gone with it — the survivors adopt
        // its range from the checkpoint, so reporting its stale local
        // population would double-count every adopted agent in the
        // launcher's aggregate snapshot.
        let killed = self
            .comm
            .chaos_plan()
            .and_then(|p| p.kill_at_iteration)
            .is_some_and(|k| self.iteration >= k);
        RankOutcome {
            final_agents: if killed { 0 } else { self.rm.len() as u64 },
            final_snapshot: if killed {
                Vec::new()
            } else {
                self.rm
                    .iter()
                    .map(|a| (a.position, a.diameter, a.kind.class_id()))
                    .collect()
            },
            aura_stream_crc: self.comm.stream_audit_crc(),
            wire_bytes_sent: self.comm.wire_bytes_sent,
            metrics: self.take_metrics(),
            stats_history: std::mem::take(&mut self.stats_history),
            frames: std::mem::take(&mut self.frames),
        }
    }

    fn take_metrics(&mut self) -> RankMetrics {
        self.metrics.network_secs = self.comm.network_secs;
        std::mem::take(&mut self.metrics)
    }

    /// One simulation iteration (Fig. 1 steps 1–4 + periodic services).
    pub fn iterate(&mut self) {
        let iter_timer = Timer::start();
        let cpu_timer = crate::util::timing::CpuTimer::start();
        self.pool_cpu_secs = 0.0;
        // Flush the transport's bounded completion window up front: on the
        // nonblocking UDS/shm paths a frame queued behind a slow peer last
        // iteration must not wait for the next receive to make progress
        // (the bounded completion-callback latency contract — see
        // `Transport::pump`). A no-op on the in-process backend.
        self.comm.pump();
        self.aura_update();
        if self.model.uses_mechanics() {
            self.mechanics_phase();
        }
        self.behavior_phase();
        self.model_phase();
        self.migration_phase();
        if self.cfg.balance_every > 0
            && self.iteration > 0
            && self.iteration % self.cfg.balance_every as u64 == 0
            && self.cfg.balance_method != BalanceMethod::Off
        {
            self.balance_phase();
        }
        if self.cfg.rebalance_every > 0
            && self.iteration > 0
            && self.iteration % self.cfg.rebalance_every as u64 == 0
        {
            self.rebalance_phase();
        }
        if self.cfg.sort_every > 0 && self.iteration > 0 && self.iteration % self.cfg.sort_every as u64 == 0
        {
            self.sort_phase();
        }
        if let Some(vis) = self.cfg.vis {
            if self.iteration % vis.every as u64 == 0 {
                self.visualization_phase();
            }
        }
        if self.cfg.checkpoint_every > 0
            && self.iteration > 0
            && self.iteration % self.cfg.checkpoint_every as u64 == 0
        {
            self.checkpoint_phase();
        }
        self.harvest_fault_metrics();
        self.record_stats();
        self.update_memory_accounting();
        self.iteration += 1;
        self.last_iteration_secs = iter_timer.elapsed_secs();
        self.metrics.iteration_secs.push(self.last_iteration_secs);
        self.metrics
            .iteration_cpu_secs
            .push(cpu_timer.elapsed_secs() + self.pool_cpu_secs);
    }

    // -------------------------------------------------------------------
    // Step 1: aura update
    // -------------------------------------------------------------------

    fn aura_update(&mut self) {
        // Before anything that depends on the neighbor set: apply death
        // notices from peers. A rank that never waits on the dead peer
        // directly (not a neighbor of it) still learns of the death here
        // and reshards exactly like the rank that detected it — the
        // ownership map it computes is identical (a pure function of the
        // agreed checkpoint), so the survivors converge on the same
        // partition within an iteration of each other.
        self.liveness_control_phase();
        let t = crate::util::timing::CpuTimer::start();
        self.nsg.clear_aura();
        // Last iteration's receive buffers go back to the pool — the
        // in-buffer aura storage cycles instead of reallocating.
        self.aura.recycle_into(&mut self.view_pool);
        let radius = self.model.interaction_radius();
        let me = self.rank;
        if self.neighbors_dirty {
            self.neighbors_cache = self.grid.neighbor_ranks(me);
            self.neighbors_dirty = false;
        }

        // Peers that detected stream damage they cannot repair by
        // retransmission ask for a restart: drain their RESYNC requests
        // before encoding so this iteration's wire to them is a full
        // refresh (self-healing delta streams; see ARCHITECTURE.md
        // "Fault tolerance").
        let mut resyncs = std::mem::take(&mut self.resync_scratch);
        resyncs.clear();
        self.comm.drain_resync_requests(&mut resyncs);
        for &(peer, tag) in &resyncs {
            self.codec.force_full((peer, tag));
        }
        self.resync_scratch = resyncs;

        // Select aura agents per destination (§2.1: exact radius bands,
        // narrower than the partition box). All scratch is reused across
        // iterations; only a neighbor-set change rebuilds the map.
        let mut per_dest = std::mem::take(&mut self.aura_per_dest);
        if per_dest.len() != self.neighbors_cache.len()
            || per_dest.iter().zip(&self.neighbors_cache).any(|((r, _), &n)| *r != n)
        {
            per_dest = self.neighbors_cache.iter().map(|&r| (r, Vec::new())).collect();
        } else {
            for (_, ids) in per_dest.iter_mut() {
                ids.clear();
            }
        }
        let mut targets = std::mem::take(&mut self.rank_scratch);
        for a in self.rm.iter() {
            self.grid.ranks_within_into(a.position, radius, me, &mut targets);
            for &t in &targets {
                if let Some(slot) = per_dest.iter_mut().find(|(r, _)| *r == t) {
                    slot.1.push(a.local_id);
                }
            }
        }
        self.rank_scratch = targets;
        // Global-id translation happens here (§2.5: only when an agent is
        // actually transferred).
        for (_, ids) in &per_dest {
            for &id in ids {
                self.rm.ensure_global_id(id);
            }
        }
        // Encode every destination in parallel on the rank's pool and
        // stream each wire the moment its encode completes (ROADMAP
        // "overlap encode with send"): the per-destination encodes are
        // independent — each streams the selected agents straight out of
        // the SoA columns through its own channel's delta reference and
        // payload buffer into its own reused wire buffer — and the rank
        // thread publishes each finished wire while later encodes still
        // run, so destination 0's send overlaps destination N's
        // compression. Wires are encoded after a reserved FRAME_HEADER
        // gap, so a single-chunk message is published to the transport
        // *in place* (`send_batched_framed`): the mailbox frame is the
        // very buffer the encoder wrote, and a recycled buffer from the
        // shared frame pool is swapped back into the job for the next
        // iteration — zero copies between encode and decode, and no
        // data-bearing allocation (only the frame's fixed-size refcount
        // cell, the MPI_Request analog). Completion order only moves
        // send *start* times; wire bytes per destination stay
        // byte-identical for any thread count.
        let mut jobs = std::mem::take(&mut self.aura_jobs);
        let encode_cpu = {
            let comm = &mut self.comm;
            let metrics = &mut self.metrics;
            let iteration = self.iteration as u32;
            let chunk_bytes = self.cfg.chunk_bytes;
            self.codec.encode_rm_overlapped(
                tags::AURA,
                &self.rm,
                &per_dest,
                &mut jobs,
                &self.pool,
                FRAME_HEADER,
                |i, wire, stats| {
                    let (dest, ids) = &per_dest[i];
                    metrics.count(Counter::AuraAgentsSent, ids.len() as u64);
                    metrics.add_op(Op::Serialize, stats.serialize_secs);
                    metrics.add_op(Op::Compress, stats.compress_secs);
                    metrics.count(Counter::BytesSentRaw, stats.raw_bytes as u64);
                    metrics.count(Counter::BytesSentWire, stats.wire_bytes as u64);
                    let frames = metrics.timed_cpu(Op::Transfer, || {
                        send_batched_framed(comm, *dest, tags::AURA, iteration, wire, chunk_bytes)
                    });
                    // Chunked sends count per frame, so the wire/messages
                    // ratio reflects what the fabric saw.
                    metrics.count(Counter::MessagesSent, frames as u64);
                },
            )
        };
        self.pool_cpu_secs += encode_cpu;
        self.aura_jobs = jobs;
        self.aura_per_dest = per_dest;
        // Streaming ingest (ROADMAP "decode-on-arrival"): the rank thread
        // keeps receiving frames from ANY neighbor in arrival order (no
        // fixed-rank-order blocking wait) and hands each source's wire to
        // a pool decode worker the moment it completes — a single-frame
        // message is the sender's published buffer, borrowed in place
        // (zero receive-side copies), so the first source's decompression
        // and delta restore overlap the last source's network wait.
        // Blocked wall time, staging-copy CPU and copied bytes are
        // metered separately (the clock-skew fix + frame-granular
        // reassembly accounting). Jobs land in source order regardless of
        // arrival order and thread count.
        let mut rx_jobs = std::mem::take(&mut self.aura_rx_jobs);
        let recv_timeout_ms = self.cfg.recv_timeout_ms;
        let msg_id = self.iteration as u32;
        let (rres, decode_cpu) = {
            let reassembler = &mut self.reassembler;
            let comm = &mut self.comm;
            let srcs = &self.neighbors_cache;
            self.codec.decode_pooled_streamed(
                tags::AURA,
                srcs,
                &mut rx_jobs,
                &mut self.view_pool,
                &self.pool,
                |staging, feed: &mut dyn FnMut(usize, WireSlot)| {
                    if recv_timeout_ms > 0 {
                        // Bounded reliable receive: verify frames, NACK
                        // missing chunks, give up after the deadline
                        // instead of blocking the rank forever.
                        let retry = RetryConfig {
                            slice: std::time::Duration::from_millis(2),
                            max_slices: (recv_timeout_ms / 2).max(1) as u32,
                        };
                        recv_all_batched_reliable(
                            reassembler,
                            comm,
                            srcs,
                            tags::AURA,
                            msg_id,
                            staging,
                            retry,
                            |k, slot| feed(k, slot),
                        )
                    } else {
                        Ok(recv_all_batched_streaming(
                            reassembler,
                            comm,
                            srcs,
                            tags::AURA,
                            staging,
                            feed,
                        ))
                    }
                },
            )
        };
        let rstats = match rres {
            Ok(s) => s,
            Err(e) => self.on_receive_failure(e),
        };
        self.metrics.add_op(Op::Transfer, rstats.wait_secs);
        self.metrics.add_op(Op::Reassembly, rstats.reassembly_secs);
        self.metrics.count(Counter::MessagesReceived, rstats.frames);
        self.metrics.count(Counter::BytesReassembled, rstats.copied_bytes);
        self.metrics.count(Counter::RetriesRequested, rstats.retries_sent);
        self.pool_cpu_secs += decode_cpu;
        let mut decoded = std::mem::take(&mut self.aura_decoded);
        decoded.clear();
        for (k, job) in rx_jobs.iter_mut().enumerate() {
            self.metrics.add_op(Op::Deserialize, job.stats.deserialize_secs);
            self.metrics.add_op(Op::Decompress, job.stats.decompress_secs);
            if let Some(d) = job.take() {
                decoded.push(d);
                continue;
            }
            if job.error.take().is_some() {
                // The wire survived the transport's frame checks but the
                // decode failed (typically a delta against a reference
                // this rank no longer holds). Drop the source's aura for
                // this iteration, reset the channel and ask the peer to
                // restart the stream with a full refresh.
                let src = self.neighbors_cache[k];
                self.metrics.count(Counter::FaultsDetected, 1);
                self.metrics.count(Counter::StreamResyncs, 1);
                self.codec.reset_rx((src, tags::AURA));
                self.comm.request_resync(src, tags::AURA);
            }
            // No decoded view and no error: the bounded receive gave up
            // on this source (already handled by on_receive_failure); it
            // contributes no aura this iteration.
        }
        self.aura_rx_jobs = rx_jobs;
        // Mirror the hot columns into per-source pre-reserved ranges
        // (prefix sums in source order → aura ids are deterministic for
        // any arrival order and thread count), then register the whole
        // batch in the NSG through the Morton-sharded bulk fill (serial
        // add_aura fallback when a source's view isn't cell-sorted).
        let mut ranges = std::mem::take(&mut self.aura_ranges);
        let mirror_cpu = self.aura.add_sources(&mut decoded, &self.pool, &mut ranges);
        self.pool_cpu_secs += mirror_cpu;
        self.aura_decoded = decoded;
        let nsg_cpu = self.nsg.add_aura_ranges(&ranges, self.aura.positions(), &self.pool);
        self.pool_cpu_secs += nsg_cpu;
        self.aura_ranges = ranges;
        self.metrics.add_op(Op::AuraUpdate, t.elapsed_secs());
    }

    // -------------------------------------------------------------------
    // Step 2: mechanics via the AOT kernel
    // -------------------------------------------------------------------

    fn mechanics_phase(&mut self) {
        let t = crate::util::timing::CpuTimer::start();
        let params = self.model.mechanics_params();
        let radius = self.model.interaction_radius();
        self.ids_scratch.clear();
        self.rm.collect_ids(&mut self.ids_scratch);
        let n = self.ids_scratch.len();
        if n == 0 {
            self.metrics.add_op(Op::AgentOps, t.elapsed_secs());
            return;
        }
        // One (padded) gather slot per AOT_N group; the pool grows to the
        // population high-water mark and is reused every iteration, so
        // the gather performs no steady-state allocation.
        let nb = n.div_ceil(AOT_N);
        while self.gather.len() < nb {
            self.gather.push(GatherSlot::new(AOT_N, AOT_K));
        }
        let pool = self.pool;
        {
            // Gather neighbor batches in parallel (read-only over agent
            // state): agent and neighbor attributes stream from the
            // ResourceManager's SoA columns instead of Vec<Option<Agent>>.
            let rm = &self.rm;
            let nsg = &self.nsg;
            let aura = &self.aura;
            let model = &self.model;
            let ids = &self.ids_scratch;
            let pool_cpu = pool.for_each_mut_timed(&mut self.gather[..nb], |bi, slot| {
                let start = bi * AOT_N;
                let end = (start + AOT_N).min(n);
                slot.batch.clear();
                slot.batch.live = end - start;
                for (row, &id) in ids[start..end].iter().enumerate() {
                    debug_assert!(rm.get(id).is_some(), "stale id in mechanics snapshot");
                    let pos = rm.col_position(id.index);
                    let kind = rm.col_kind(id.index);
                    slot.batch.set_agent(row, pos, rm.col_diameter(id.index));
                    // Bounded K-nearest selection (max-heap): candidates
                    // stream through a K-entry heap in deterministic
                    // total order — nearest first, ties by position —
                    // independent of rank count / NSG layout; the
                    // per-agent sort over all candidates is gone.
                    slot.knn.clear();
                    nsg.for_each_neighbor(
                        pos,
                        radius,
                        Some(NsgEntry::Owned(id)),
                        |entry, npos, d2| {
                            let (diam, nkind) = match entry {
                                NsgEntry::Owned(nid) => {
                                    debug_assert!(rm.get(nid).is_some(), "stale NSG neighbor");
                                    (rm.col_diameter(nid.index), rm.col_kind(nid.index))
                                }
                                NsgEntry::Aura(ai) => (aura.diameter(ai), aura.kind(ai)),
                            };
                            let adh = model.adhesion_scale(&kind, &nkind);
                            slot.knn.push((d2, npos, diam, adh));
                        },
                    );
                    for (j, (_, pos, diam, adh)) in slot.knn.sorted().iter().enumerate() {
                        slot.batch.set_neighbor(row, j, *pos, *diam, (*adh).max(1e-6));
                    }
                }
            });
            // Pool-worker CPU is invisible to the rank thread's CPU clock;
            // charge the parallel region's critical path to this iteration.
            self.pool_cpu_secs += pool_cpu;
        }

        // Execute (PJRT service or native) into each slot's reused
        // displacement out-buffer and apply through the O(1) position
        // write-through.
        let whole = self.grid.whole();
        let mech = &self.mech;
        for (bi, slot) in self.gather[..nb].iter_mut().enumerate() {
            mech.compute_into(&slot.batch, params, &mut slot.disp);
            for row in 0..slot.batch.live {
                let id = self.ids_scratch[bi * AOT_N + row];
                let d = slot.disp[row];
                if d == Vec3::ZERO {
                    continue;
                }
                let pos = self.rm.col_position(id.index) + d;
                let pos = self.cfg.boundary.apply(pos, &whole);
                // Guarded like World::move_agent: a stale id must never
                // reach the NSG's add-if-unknown path.
                if self.rm.set_position(id, pos) {
                    self.nsg.update_position(NsgEntry::Owned(id), pos);
                }
            }
        }
        self.metrics.count(Counter::AgentUpdates, n as u64);
        self.metrics.add_op(Op::AgentOps, t.elapsed_secs());
    }

    // -------------------------------------------------------------------
    // Step 3a: arena behavior sweep
    // -------------------------------------------------------------------

    /// Execute every agent-attached behavior in one cache-linear pass
    /// over the flat arena: the parallel sweep mutates behavior
    /// parameters in place and returns structural effects in slot order;
    /// the rank thread then applies those effects serially (moves through
    /// the boundary + NSG, kind/diameter writes through the SoA guard,
    /// division children inheriting the parent's behavior set). Models
    /// whose agents carry no behaviors skip the phase entirely.
    fn behavior_phase(&mut self) {
        if self.rm.behavior_count() == 0 {
            return;
        }
        let t = crate::util::timing::CpuTimer::start();
        let executed = self.rm.behavior_count() as u64;
        self.ids_scratch.clear();
        self.rm.collect_ids(&mut self.ids_scratch);
        // Per-agent RNG streams key on the (constant) global id; mint ids
        // up front so the sweep itself never draws from the slot index.
        for &id in &self.ids_scratch {
            self.rm.ensure_global_id(id);
        }
        let ids = std::mem::take(&mut self.ids_scratch);
        let pool = self.pool;
        let ctx = BehaviorCtx {
            iteration: self.iteration,
            seed: self.cfg.seed,
            nsg: &self.nsg,
            aura: &self.aura,
        };
        let (effects, sweep_cpu) = self
            .rm
            .behavior_sweep(&pool, &ids, |_k, id, cols, bs| behavior::run_slot(id, cols, bs, &ctx));
        self.pool_cpu_secs += sweep_cpu;
        self.ids_scratch = ids;
        let whole = self.grid.whole();
        for eff in effects {
            if let Some(d) = eff.new_diameter {
                if let Some(mut a) = self.rm.get_mut(eff.id) {
                    a.diameter = d;
                }
            }
            if let Some(kind) = eff.new_kind {
                if let Some(mut a) = self.rm.get_mut(eff.id) {
                    a.kind = kind;
                }
            }
            if let Some(p) = eff.new_pos {
                let p = self.cfg.boundary.apply(p, &whole);
                if self.rm.set_position(eff.id, p) {
                    self.nsg.update_position(NsgEntry::Owned(eff.id), p);
                }
            }
            if let Some(mut child) = eff.child {
                // The child inherits the parent's (post-sweep) behavior
                // set — copied out of the arena before the add can grow
                // the pool under us.
                let bs: Vec<Behavior> =
                    self.rm.behaviors(eff.id).map(<[Behavior]>::to_vec).unwrap_or_default();
                child.position = self.cfg.boundary.apply(child.position, &whole);
                let cid = self.rm.add_with_behaviors(child, &bs);
                let pos = self.rm.get(cid).unwrap().position;
                self.nsg.add(NsgEntry::Owned(cid), pos);
            }
        }
        self.metrics.count(Counter::BehaviorsExecuted, executed);
        self.metrics.add_op(Op::Behavior, t.elapsed_secs());
    }

    // -------------------------------------------------------------------
    // Step 3b: model behaviors
    // -------------------------------------------------------------------

    fn model_phase(&mut self) {
        let t = crate::util::timing::CpuTimer::start();
        let mut world = World::new(
            self.rank,
            self.iteration,
            &mut self.rm,
            &mut self.nsg,
            &self.aura,
            &mut self.rng,
            self.cfg.whole_space(),
            self.cfg.boundary,
            self.model.interaction_radius(),
            self.pool,
        );
        self.model.step(&mut world);
        let pool_cpu = world.take_pool_cpu();
        let World { spawns, removals, .. } = world;
        self.pool_cpu_secs += pool_cpu;
        if !self.model.uses_mechanics() {
            self.metrics.count(Counter::AgentUpdates, self.rm.len() as u64);
        }
        for id in removals {
            if self.rm.remove(id).is_some() {
                self.nsg.remove(NsgEntry::Owned(id));
            }
        }
        for (agent, bs) in spawns.iter() {
            let id = self.rm.add_with_behaviors(*agent, bs);
            let pos = self.rm.get(id).unwrap().position;
            self.nsg.add(NsgEntry::Owned(id), pos);
        }
        self.metrics.add_op(Op::AgentOps, t.elapsed_secs());
    }

    // -------------------------------------------------------------------
    // Step 4: migration
    // -------------------------------------------------------------------

    fn migration_phase(&mut self) {
        let t = crate::util::timing::CpuTimer::start();
        let me = self.rank;
        let size = self.comm.size();
        // Who leaves? (The replicated partition map makes the owner lookup
        // local — the paper's collective-lookup fallback is unnecessary.)
        // Scratch buffers persist across iterations; in the common
        // nobody-leaves case this whole phase is allocation-free.
        let mut leaving = std::mem::take(&mut self.migration_leaving);
        leaving.clear();
        for a in self.rm.iter() {
            let owner = self.grid.owner_of_pos(a.position);
            if owner != me {
                leaving.push((owner, a.local_id));
            }
        }
        let mut per_dest = std::mem::take(&mut self.migration_per_dest);
        if per_dest.len() != size {
            per_dest = (0..size).map(|_| Vec::new()).collect();
        } else {
            for v in per_dest.iter_mut() {
                v.clear();
            }
        }
        for (dest, id) in leaving.drain(..) {
            self.rm.ensure_global_id(id);
            per_dest[dest as usize].push(id);
        }
        self.migration_leaving = leaving;
        let migrated: u64 = per_dest.iter().map(|v| v.len() as u64).sum();
        self.metrics.count(Counter::AgentsMigratedOut, migrated);
        // Encode while the leavers are still resident (all-to-all; empty
        // payloads for idle pairs): the columnar writer streams agent
        // headers out of the SoA columns and behavior tails straight out
        // of the flat arena — no owned `Agent` copy, no per-agent
        // behavior Vec.
        let payloads: Vec<Vec<u8>> = per_dest
            .iter()
            .enumerate()
            .map(|(d, ids)| {
                if d == me as usize {
                    return Vec::new();
                }
                let (wire, es) =
                    self.migration_codec.encode_rm((d as u32, tags::MIGRATION), &self.rm, ids);
                self.metrics.add_op(Op::Serialize, es.serialize_secs);
                self.metrics.add_op(Op::Compress, es.compress_secs);
                self.metrics.count(Counter::BytesSentRaw, es.raw_bytes as u64);
                self.metrics.count(Counter::BytesSentWire, wire.len() as u64);
                wire
            })
            .collect();
        // Now the wires exist: drop the migrated-out agents (their arena
        // extents free for reuse); the id buffers keep their capacity.
        for ids in per_dest.iter_mut() {
            for id in ids.drain(..) {
                self.rm.remove(id);
                self.nsg.remove(NsgEntry::Owned(id));
            }
        }
        self.migration_per_dest = per_dest;
        let round = self.a2a_round;
        self.a2a_round += 1;
        let received =
            self.metrics.timed_cpu(Op::Transfer, || self.comm.alltoallv(payloads, round));
        for (src, wire) in received.into_iter().enumerate() {
            if wire.is_empty() {
                continue;
            }
            let (decoded, ds) = match self.migration_codec.decode_pooled(
                (src as u32, tags::MIGRATION),
                &wire,
                &mut self.view_pool,
            ) {
                Ok(ok) => ok,
                Err(_) => {
                    // Migration wires are delta-free one-shots; a decode
                    // failure means the payload itself is damaged and
                    // unrecoverable. Count it and keep the rank alive —
                    // never panic on wire-derived bytes.
                    self.metrics.count(Counter::FaultsDetected, 1);
                    continue;
                }
            };
            self.metrics.add_op(Op::Deserialize, ds.deserialize_secs);
            self.metrics.add_op(Op::Decompress, ds.decompress_secs);
            // Migrated agents move from the wire straight into owned
            // storage (fresh local ids — the local/global id translation
            // of §2.5) with their behavior tails ingested directly into
            // the arena; the decode buffer goes back to the pool.
            let nsg = &mut self.nsg;
            decoded.ingest_into_rm(&mut self.rm, &mut self.view_pool, |id, pos| {
                nsg.add(NsgEntry::Owned(id), pos);
            });
        }
        self.metrics.add_op(Op::Migration, t.elapsed_secs());
    }

    // -------------------------------------------------------------------
    // Fault tolerance: recovery ladder (retry → resync → restore)
    // -------------------------------------------------------------------

    fn checkpoint_dir(&self) -> std::path::PathBuf {
        std::path::Path::new(&self.cfg.artifacts_dir)
            .join("checkpoints")
            .join(&self.cfg.name)
    }

    /// Periodic safety net: write an atomic, CRC-protected snapshot of
    /// the owned agents. A write failure is non-fatal — it only widens
    /// the window the last rung of the recovery ladder can rewind to.
    fn checkpoint_phase(&mut self) {
        let t = crate::util::timing::CpuTimer::start();
        let dir = self.checkpoint_dir();
        if std::fs::create_dir_all(&dir).is_ok() {
            checkpoint::write_checkpoint(&dir, self.rank, self.iteration, &mut self.rm).ok();
            self.write_due_manifests(&dir);
        }
        self.metrics.add_op(Op::Checkpoint, t.elapsed_secs());
    }

    /// Manifest any recent checkpoint round whose per-rank files are all
    /// on disk and valid. Manifests lag checkpoints by up to one period:
    /// a round is manifested only once every live rank's file verifies,
    /// decided purely from the files themselves — no collective — so the
    /// path keeps working while peers are slow or already dead. Any rank
    /// may write: the manifest bytes are a pure function of the files,
    /// so concurrent writers race to atomically rename identical
    /// content.
    fn write_due_manifests(&mut self, dir: &std::path::Path) {
        let period = self.cfg.checkpoint_every as u64; // > 0 in this phase
        // Manifest entries carry explicit rank ids (format v2), so any
        // live set manifests — including the non-prefix survivor set a
        // mid-rank death leaves behind.
        let size = self.comm.size() as u32;
        let live: Vec<u32> = (0..size).filter(|&r| !self.comm.is_dead(r)).collect();
        if live.is_empty() {
            return;
        }
        let mut round = self.iteration - self.iteration % period;
        for _ in 0..4 {
            if round == 0 {
                break;
            }
            // A checkpoint file from a now-dead rank means this round
            // predates the death and involved more ranks than are live
            // now; manifesting it with today's narrower rank set would
            // silently drop the dead ranks' agents on restore.
            let predates_death = (0..size).filter(|&r| self.comm.is_dead(r)).any(|r| {
                dir.join(checkpoint::checkpoint_name(r, round)).exists()
            });
            if !dir.join(checkpoint::manifest_name(round)).exists() && !predates_death {
                let mut ranks = Vec::with_capacity(live.len());
                for &r in &live {
                    match checkpoint::verify_checkpoint(
                        dir.join(checkpoint::checkpoint_name(r, round)),
                    ) {
                        Ok((info, crc)) if info.rank == r && info.iteration == round => {
                            ranks.push(checkpoint::ManifestEntry {
                                rank: r,
                                agents: info.agents,
                                crc,
                            });
                        }
                        _ => {
                            ranks.clear();
                            break;
                        }
                    }
                }
                if ranks.len() == live.len() {
                    let m = checkpoint::Manifest {
                        iteration: round,
                        rank_count: live.len() as u32,
                        ranks,
                    };
                    checkpoint::write_manifest(dir, &m).ok();
                }
            }
            round -= period;
        }
    }

    /// The bounded receive gave up: purge the half-assembled messages,
    /// restart the damaged streams, and — as the last rung of the ladder
    /// — rewind owned state to the newest valid checkpoint if one
    /// exists. Returns empty stats so the iteration continues (the
    /// failed sources contribute no aura); the rank never deadlocks or
    /// panics on a dead peer.
    fn on_receive_failure(&mut self, e: CommError) -> crate::comm::batching::RecvAllStats {
        let failed: Vec<u32> = match e {
            CommError::RetriesExhausted { pending, .. } => pending,
            CommError::Timeout { .. } => self.neighbors_cache.clone(),
            CommError::RankDead { dead, .. } => {
                // Silence escalated past the retry ladder: the peer is
                // gone, not slow. Adopt its orphaned range instead of
                // resyncing with it.
                self.on_ranks_dead(&dead);
                return crate::comm::batching::RecvAllStats::default();
            }
        };
        for &src in &failed {
            self.metrics.count(Counter::FaultsDetected, 1);
            self.metrics.count(Counter::StreamResyncs, 1);
            self.reassembler.purge(src, tags::AURA);
            // The skipped message leaves the incoming delta chain with a
            // stale reference; restart it.
            self.codec.reset_rx((src, tags::AURA));
            self.comm.request_resync(src, tags::AURA);
        }
        self.recover_from_checkpoint();
        crate::comm::batching::RecvAllStats::default()
    }

    /// Restore owned agents from the newest checkpoint that passes its
    /// CRC, rebuild the search grid, and force every outgoing delta
    /// stream to a full refresh (receivers hold references to the
    /// pre-rewind state). Returns `false` when no valid checkpoint
    /// exists — the simulation then continues degraded instead of dying.
    pub fn recover_from_checkpoint(&mut self) -> bool {
        let dir = self.checkpoint_dir();
        let restored = match checkpoint::restore_latest_valid(&dir, self.rank) {
            Ok(Some((_info, agents))) => {
                self.rm = ResourceManager::new(self.rank);
                checkpoint::restore_into(&mut self.rm, agents);
                self.nsg =
                    NeighborSearchGrid::new(self.grid.whole(), self.model.interaction_radius());
                self.ids_scratch.clear();
                self.rm.collect_ids(&mut self.ids_scratch);
                for &id in &self.ids_scratch {
                    self.nsg.add(NsgEntry::Owned(id), self.rm.col_position(id.index));
                }
                true
            }
            _ => false,
        };
        if restored {
            self.codec.force_full_all();
            self.metrics.count(Counter::CheckpointRestores, 1);
        }
        restored
    }

    /// Drain the liveness control plane (heartbeats, peer death notices)
    /// and reshard if a notice named a rank not yet known dead. No-op
    /// when liveness is off.
    fn liveness_control_phase(&mut self) {
        if !self.comm.liveness_enabled() {
            return;
        }
        let mut newly_dead = Vec::new();
        self.comm.drain_control_liveness(&mut newly_dead);
        if !newly_dead.is_empty() {
            self.on_ranks_dead(&newly_dead);
        }
    }

    /// Peers were declared dead (local liveness escalation or another
    /// rank's death notice): adopt their orphaned ranges. The ladder is
    /// detect → agree (newest manifest whose checkpoints all verify) →
    /// reshard (RCB over the merged checkpointed population across the
    /// survivor rank ids — *any* set, prefix or not, since manifest
    /// entries carry explicit ranks) → resume. Falls back to the plain
    /// per-rank restore only when no manifest agreement exists; either
    /// way the rank keeps running — rank death is a data-loss boundary
    /// only in the degraded fallback.
    fn on_ranks_dead(&mut self, dead: &[u32]) {
        let t = crate::util::timing::CpuTimer::start();
        self.metrics.count(Counter::RanksLost, dead.len() as u64);
        // Tell everyone else before rebuilding: peers that never wait on
        // the dead ranks directly must reshard too, or the survivors'
        // neighbor sets stop agreeing.
        self.comm.announce_dead(dead);
        // The aborted exchange leaves half-assembled messages and broken
        // delta chains behind; clear the transport state wholesale.
        for src in 0..self.comm.size() as u32 {
            self.reassembler.purge(src, tags::AURA);
        }
        for &d in dead {
            self.codec.reset_rx((d, tags::AURA));
        }
        self.comm.cancel_pending(tags::AURA);
        let dir = self.checkpoint_dir();
        let size = self.comm.size() as u32;
        let live: Vec<u32> = (0..size).filter(|&r| !self.comm.is_dead(r)).collect();
        let agreed = checkpoint::latest_agreed_iteration(&dir).ok().flatten();
        let resharded = match agreed {
            Some(m) if live.contains(&self.rank) => self.reshard_restore(&dir, &m, &live, dead),
            _ => false,
        };
        if !resharded {
            // Degraded rung: rewind locally like any other unrecoverable
            // receive failure; the dead ranks' agents stay lost until an
            // operator intervenes.
            self.recover_from_checkpoint();
            self.neighbors_dirty = true;
        }
        // The neighbor set changed: parked transport buffers sized for
        // the old fan-in/fan-out may never be needed again.
        self.view_pool.shrink_to_watermark();
        self.comm.frame_pool().shrink_to_watermark();
        self.metrics.add_op(Op::Reshard, t.elapsed_secs());
    }

    /// The elastic rung: re-run RCB over the merged population of the
    /// agreed checkpoint across the `survivors` rank ids, rebuild this
    /// rank's owned state from its share, and restart every stream.
    fn reshard_restore(
        &mut self,
        dir: &std::path::Path,
        m: &checkpoint::Manifest,
        survivors: &[u32],
        dead: &[u32],
    ) -> bool {
        let before: Vec<u32> = self.grid.owners().to_vec();
        let old_ids = m.rank_ids();
        let out = match checkpoint::restore_resharded_mapped(
            dir,
            m.iteration,
            &old_ids,
            survivors,
            &mut self.grid,
            self.rank,
        ) {
            Ok(out) => out,
            Err(_) => return false,
        };
        let adopted = before
            .iter()
            .zip(self.grid.owners())
            .filter(|(old, new)| dead.contains(old) && **new == self.rank)
            .count() as u64;
        self.metrics.count(Counter::OrphanedBoxesAdopted, adopted);
        self.rm = ResourceManager::new(self.rank);
        checkpoint::restore_into(&mut self.rm, out.agents);
        self.nsg = NeighborSearchGrid::new(self.grid.whole(), self.model.interaction_radius());
        self.ids_scratch.clear();
        self.rm.collect_ids(&mut self.ids_scratch);
        for &id in &self.ids_scratch {
            self.nsg.add(NsgEntry::Owned(id), self.rm.col_position(id.index));
        }
        // Receivers hold delta references to the pre-reshard streams;
        // every outgoing channel restarts with a full refresh, and the
        // neighbor cache is rebuilt from the new ownership.
        self.codec.force_full_all();
        self.neighbors_dirty = true;
        self.metrics.count(Counter::ReshardRestores, 1);
        true
    }

    /// Fold the transport's cumulative fault/overhead counters into the
    /// rank metrics as per-iteration deltas (the counters live on the
    /// communicator and reassembler and survive across iterations).
    fn harvest_fault_metrics(&mut self) {
        let cs = self.comm.checksum_secs + self.reassembler.checksum_secs;
        if cs > self.checksum_secs_seen {
            self.metrics.add_op(Op::Checksum, cs - self.checksum_secs_seen);
            self.checksum_secs_seen = cs;
        }
        let det = self.reassembler.faults.detected();
        if det > self.faults_detected_seen {
            self.metrics.count(Counter::FaultsDetected, det - self.faults_detected_seen);
            self.faults_detected_seen = det;
        }
        let served = self.comm.retransmits_served();
        if served > self.retransmits_seen {
            self.metrics.count(Counter::FramesRetransmitted, served - self.retransmits_seen);
            self.retransmits_seen = served;
        }
        let injected = self.comm.chaos_stats().injected();
        if injected > self.faults_injected_seen {
            self.metrics.count(Counter::FaultsInjected, injected - self.faults_injected_seen);
            self.faults_injected_seen = injected;
        }
        let ts = self.comm.transport_stats();
        if ts.send_stalls > self.transport_stalls_seen {
            self.metrics
                .count(Counter::TransportSendStalls, ts.send_stalls - self.transport_stalls_seen);
            self.transport_stalls_seen = ts.send_stalls;
        }
        if ts.inline_fallbacks > self.inline_fallbacks_seen {
            self.metrics.count(
                Counter::TransportInlineFallbacks,
                ts.inline_fallbacks - self.inline_fallbacks_seen,
            );
            self.inline_fallbacks_seen = ts.inline_fallbacks;
        }
        let a2a_rej = self.comm.alltoall_rejects();
        if a2a_rej > self.a2a_rejects_seen {
            self.metrics.count(Counter::FaultsDetected, a2a_rej - self.a2a_rejects_seen);
            self.a2a_rejects_seen = a2a_rej;
        }
        let a2a_nacks = self.comm.alltoall_nacks();
        if a2a_nacks > self.a2a_nacks_seen {
            self.metrics.count(Counter::RetriesRequested, a2a_nacks - self.a2a_nacks_seen);
            self.a2a_nacks_seen = a2a_nacks;
        }
    }

    // -------------------------------------------------------------------
    // Periodic: load balancing
    // -------------------------------------------------------------------

    fn balance_phase(&mut self) {
        let t = crate::util::timing::CpuTimer::start();
        // Weight field: owned agents per box × per-agent runtime (§2.4.5).
        let local = weights::compute_box_weights(&self.grid, &self.nsg, self.rank, self.last_iteration_secs);
        let global = self.comm.allreduce_sum_f64(&local);
        for (i, w) in global.iter().enumerate() {
            self.grid.set_weight(i, *w);
        }
        let before: Vec<u32> = self.grid.owners().to_vec();
        match self.cfg.balance_method {
            BalanceMethod::Rcb => {
                let owners = rcb::rcb_partition(&self.grid, self.comm.size() as u32);
                self.grid.set_owners(owners);
            }
            BalanceMethod::Diffusive => {
                let runtimes = self.comm.allreduce_sum_f64(&{
                    let mut v = vec![0.0; self.comm.size()];
                    v[self.rank as usize] = self.last_iteration_secs;
                    v
                });
                let transfers = diffusive::diffusive_step(&self.grid, &runtimes, 0.05, 4);
                diffusive::apply_transfers(&mut self.grid, &transfers);
            }
            BalanceMethod::Off => {}
        }
        let moved = before
            .iter()
            .zip(self.grid.owners())
            .filter(|(a, b)| a != b)
            .count() as u64;
        self.metrics.count(Counter::BoxesRebalanced, moved);
        // Obsolete speculative receives for the old neighbor set (§2.4.3),
        // and the cached neighbor-rank set must be recomputed.
        if moved > 0 {
            self.comm.cancel_pending(tags::AURA);
            self.neighbors_dirty = true;
            // The neighbor set is about to change: parked receive
            // buffers and frames sized for the old fan-in/fan-out may
            // never be needed again — trim both recycle pools to their
            // recent high-water demand (ROADMAP "buffer-memory
            // reclamation").
            self.view_pool.shrink_to_watermark();
            self.comm.frame_pool().shrink_to_watermark();
        }
        self.metrics.add_op(Op::Balancing, t.elapsed_secs());
        // Hand off agents whose boxes changed owner.
        if moved > 0 {
            self.migration_phase();
        }
    }

    // -------------------------------------------------------------------
    // Periodic: online repartitioning (live cell-range migration)
    // -------------------------------------------------------------------

    /// Plan → ship → splice → resync, with zero checkpoint involvement.
    ///
    /// Every live rank allreduces the measured box-weight field, runs the
    /// same deterministic [`replan::plan_rebalance`], and — when the plan
    /// is non-trivial — installs the new owner map and hands the affected
    /// agents off through the regular migration path (columnar TA IO
    /// wire format over whatever `Transport` backend the run uses,
    /// behavior tails streamed arena-to-arena). Donor and receiver NSG
    /// shards are updated incrementally by `migration_phase` itself;
    /// afterwards the delta channels restart with a full refresh because
    /// receivers hold references to pre-move stream state, and the
    /// buffer pools trim to their new fan-in/fan-out watermarks.
    ///
    /// The plan also fires when the live rank set differs from the
    /// current owner set regardless of imbalance — that is how a run
    /// started on `active_ranks < size` grows onto the idle ranks.
    fn rebalance_phase(&mut self) {
        let t = crate::util::timing::CpuTimer::start();
        // Zero runtime on purpose: the weight field must be a pure
        // function of simulation state (agent counts per box), never
        // wall-clock, so every rank — and every rerun — computes the
        // identical plan. Runtime-scaled heterogeneous balancing stays
        // the classic `balance_phase`'s job.
        let local = weights::compute_box_weights(&self.grid, &self.nsg, self.rank, 0.0);
        let global = self.comm.allreduce_sum_f64(&local);
        for (i, w) in global.iter().enumerate() {
            self.grid.set_weight(i, *w);
        }
        let live: Vec<u32> =
            (0..self.comm.size() as u32).filter(|&r| !self.comm.is_dead(r)).collect();
        let plan = replan::plan_rebalance(&self.grid, &live, self.cfg.rebalance_threshold);
        self.grid.clear_weights();
        let moved = match plan {
            Some(plan) if !plan.moves.is_empty() => {
                self.metrics.count(Counter::RebalancePlans, 1);
                let donated =
                    plan.moves.iter().filter(|m| m.from == self.rank).count() as u64;
                self.metrics.count(Counter::CellRangesMigrated, donated);
                self.grid.set_owners(plan.owners);
                let leaving = self
                    .rm
                    .iter()
                    .filter(|a| self.grid.owner_of_pos(a.position) != self.rank)
                    .count() as u64;
                self.metrics.count(Counter::AgentsRebalanced, leaving);
                true
            }
            _ => false,
        };
        if moved {
            // Obsolete speculative receives for the old neighbor set, and
            // the cached neighbor-rank set must be recomputed before the
            // next aura exchange.
            self.comm.cancel_pending(tags::AURA);
            self.neighbors_dirty = true;
            self.view_pool.shrink_to_watermark();
            self.comm.frame_pool().shrink_to_watermark();
        }
        self.metrics.add_op(Op::Rebalance, t.elapsed_secs());
        if moved {
            // Ship the affected agents over the regular migration path —
            // the columnar encode (behavior tails straight out of the
            // arena) and the incremental NSG remove/add on both sides
            // live there. Every rank participates in the alltoallv even
            // with nothing to donate.
            self.migration_phase();
            // Receivers hold delta references to pre-move stream state;
            // restart every outgoing channel with a full refresh.
            self.codec.force_full_all();
        }
    }

    // -------------------------------------------------------------------
    // Periodic: agent sorting (§2.5)
    // -------------------------------------------------------------------

    fn sort_phase(&mut self) {
        let t = crate::util::timing::CpuTimer::start();
        // Sort with the NSG's own quantization — origin, cell size and
        // per-axis clamped dims — so slot order lands exactly in
        // ascending Morton cell order, the precondition for the parallel
        // wholesale rebuild below.
        self.rm
            .sort_by_grid(self.grid.whole().min, self.nsg.cell_size(), self.nsg.dims());
        // Local ids changed: rebuild the NSG's owned entries in place —
        // workers bin disjoint Morton cell ranges and the arenas keep
        // their capacity (the seed path allocated a brand-new grid here
        // every sort; the §2.2.1 buffer-memory reclamation now happens
        // continuously through the ViewPool recycle loop instead).
        self.ids_scratch.clear();
        self.rm.collect_ids(&mut self.ids_scratch);
        let cpu = self.nsg.rebuild_owned(&self.ids_scratch, self.rm.positions(), &self.pool);
        // sort_by_grid uses the grid's own quantization, so the sharded
        // path must engage; a fallback here means the sort key and the
        // cell map drifted apart (see morton3_in_grid).
        debug_assert!(
            self.nsg.last_rebuild_was_parallel() || self.rm.is_empty(),
            "post-sort NSG rebuild unexpectedly took the serial fallback"
        );
        self.pool_cpu_secs += cpu;
        self.metrics.add_op(Op::NsgUpdate, t.elapsed_secs());
    }

    // -------------------------------------------------------------------
    // Periodic: in-situ visualization (§3.6)
    // -------------------------------------------------------------------

    fn visualization_phase(&mut self) {
        let t = crate::util::timing::CpuTimer::start();
        let vis = self.cfg.vis.unwrap();
        let whole = self.grid.whole();
        // Per-rank geometry pass (this is the dominant, rank-parallel cost).
        let tile = render_agents(
            vis.width,
            vis.height,
            &whole,
            self.rm
                .iter()
                .map(|a| (a.position, a.diameter, color_of_kind(&a.kind))),
        );
        // Sort-last compositing on rank 0.
        let tiles = self.comm.allgather(tile.to_bytes());
        if self.rank == 0 {
            let mut frame = Image::new(vis.width, vis.height);
            for bytes in &tiles {
                frame.composite(&Image::from_bytes(bytes));
            }
            PartitionGridOverlay { grid: &self.grid }.render(&mut frame, &whole);
            if vis.export {
                let dir = std::path::Path::new("output/frames");
                std::fs::create_dir_all(dir).ok();
                frame
                    .write_ppm(dir.join(format!("frame_{:06}.ppm", self.iteration)))
                    .ok();
            }
            self.frames.push(frame);
        }
        self.metrics.add_op(Op::Visualization, t.elapsed_secs());
    }

    // -------------------------------------------------------------------

    fn record_stats(&mut self) {
        let world = World::new(
            self.rank,
            self.iteration,
            &mut self.rm,
            &mut self.nsg,
            &self.aura,
            &mut self.rng,
            self.cfg.whole_space(),
            self.cfg.boundary,
            self.model.interaction_radius(),
            self.pool,
        );
        let stats = self.model.local_stats(&world);
        self.pool_cpu_secs += world.take_pool_cpu();
        self.stats_history.push(stats);
    }

    fn update_memory_accounting(&mut self) {
        // The transport frame pool is world-shared; attribute it to rank 0
        // so the cross-rank sum counts its parked buffers exactly once
        // (in-flight frames are briefly outside the free list — this is
        // the steady-state between-iteration footprint).
        let frame_pool_bytes =
            if self.rank == 0 { self.comm.frame_pool().approx_bytes() } else { 0 };
        let live = self.rm.approx_bytes()
            + self.nsg.approx_bytes()
            + self.grid.approx_bytes()
            + self.aura.approx_bytes()
            + self.codec.reference_bytes()
            + self.view_pool.approx_bytes()
            + frame_pool_bytes;
        if live > self.metrics.peak_mem_bytes {
            self.metrics.peak_mem_bytes = live;
        }
    }
}
