//! Scoped thread pool for intra-rank ("OpenMP-style") parallelism.
//!
//! The offline toolchain has no `rayon`; this is a minimal fork-join
//! helper over `std::thread::scope`. One pool per rank provides the
//! shared-memory parallelism of the paper's MPI-hybrid mode.

/// A fixed-width fork-join pool (stateless; threads are scoped per call,
/// which keeps rank threads independent and avoids cross-rank sharing).
#[derive(Clone, Copy, Debug)]
pub struct ThreadPool {
    threads: usize,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        ThreadPool { threads: threads.max(1) }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Map `f` over near-equal contiguous chunks of `0..len` in parallel;
    /// returns per-chunk results in order. `f(chunk_index, start, end)`.
    pub fn map_chunks<R: Send>(
        &self,
        len: usize,
        f: impl Fn(usize, usize, usize) -> R + Sync,
    ) -> Vec<R> {
        self.map_chunks_timed(len, f).0
    }

    /// Like [`map_chunks`](Self::map_chunks), additionally returning the
    /// *critical-path CPU seconds* of the parallel region: the maximum
    /// worker-thread CPU time. Worker CPU is invisible to the calling
    /// thread's `CLOCK_THREAD_CPUTIME_ID`, so the engine adds this to its
    /// per-iteration CPU accounting (the single-core-testbed parallel
    /// runtime model; see DESIGN.md).
    pub fn map_chunks_timed<R: Send>(
        &self,
        len: usize,
        f: impl Fn(usize, usize, usize) -> R + Sync,
    ) -> (Vec<R>, f64) {
        if len == 0 {
            return (Vec::new(), 0.0);
        }
        // Uniform boundaries, then the shared fork-join body.
        let nchunks = self.threads.min(len);
        let chunk = len.div_ceil(nchunks);
        let bounds: Vec<usize> = (0..=nchunks).map(|i| (i * chunk).min(len)).collect();
        self.map_parts_timed(&bounds, f)
    }

    /// Fork-join over caller-chosen contiguous partition boundaries:
    /// `bounds = [b0, b1, …, bP]` describes `P` parts `b(i)..b(i+1)`
    /// (non-decreasing; empty parts are allowed and still invoked).
    /// Unlike [`map_chunks`](Self::map_chunks), part boundaries are
    /// data-dependent — e.g. slot ranges cut at Morton-cell changes for
    /// the parallel NSG rebuild
    /// ([`NeighborSearchGrid::rebuild_owned`]). Callers should size `P`
    /// to ≈ [`threads`](Self::threads); one worker is spawned per part.
    /// Returns per-part results in order plus the region's critical-path
    /// CPU seconds (see [`map_chunks_timed`](Self::map_chunks_timed)).
    ///
    /// [`NeighborSearchGrid::rebuild_owned`]: crate::space::NeighborSearchGrid::rebuild_owned
    pub fn map_parts_timed<R: Send>(
        &self,
        bounds: &[usize],
        f: impl Fn(usize, usize, usize) -> R + Sync,
    ) -> (Vec<R>, f64) {
        debug_assert!(bounds.windows(2).all(|w| w[0] <= w[1]), "part bounds must be sorted");
        let parts = bounds.len().saturating_sub(1);
        if parts == 0 {
            return (Vec::new(), 0.0);
        }
        if parts == 1 {
            // Inline on the caller: its own CPU clock sees the work.
            return (vec![f(0, bounds[0], bounds[1])], 0.0);
        }
        let mut out: Vec<(Option<R>, f64)> = (0..parts).map(|_| (None, 0.0)).collect();
        std::thread::scope(|s| {
            let f = &f;
            let mut handles = Vec::with_capacity(parts);
            for (pi, slot) in out.iter_mut().enumerate() {
                let (start, end) = (bounds[pi], bounds[pi + 1]);
                handles.push(s.spawn(move || {
                    let t = crate::util::timing::CpuTimer::start();
                    slot.0 = Some(f(pi, start, end));
                    slot.1 = t.elapsed_secs();
                }));
            }
            for h in handles {
                h.join().expect("pool worker panicked");
            }
        });
        let critical = out.iter().map(|(_, c)| *c).fold(0.0, f64::max);
        (out.into_iter().map(|(o, _)| o.unwrap()).collect(), critical)
    }

    /// Parallel-for over `0..len`, discarding results.
    pub fn for_chunks(&self, len: usize, f: impl Fn(usize, usize, usize) + Sync) {
        self.map_chunks(len, |ci, s, e| {
            f(ci, s, e);
        });
    }

    /// Completion-ordered parallel for-each: workers pull items one at a
    /// time (`f(item_index, &mut item)`), and as each item finishes it is
    /// handed back to the **calling thread**, which runs
    /// `complete(item_index, &mut item)` immediately — while other items
    /// are still being produced. This is the overlap primitive for the
    /// aura exchange: per-destination encodes fan out on the pool and the
    /// rank thread streams each finished wire into the transport without
    /// waiting for the fork-join barrier (destination 0's send overlaps
    /// destination N's encode).
    ///
    /// `complete` runs in *completion order*, which is scheduling-
    /// dependent — callers must only do order-independent work there
    /// (sends to distinct peers, counter bumps). Item contents are
    /// produced by `f` exactly as in
    /// [`for_each_mut_timed`](Self::for_each_mut_timed), so data stays
    /// deterministic for any thread count. With one thread (or one item)
    /// everything runs inline on the caller in index order — the serial
    /// encode→send→encode→send interleaving.
    ///
    /// Returns the workers' critical-path CPU seconds; the caller's own
    /// `complete` work is visible to its own CPU clock and is not
    /// included.
    pub fn for_each_mut_completion<T: Send>(
        &self,
        items: &mut [T],
        f: impl Fn(usize, &mut T) + Sync,
        mut complete: impl FnMut(usize, &mut T),
    ) -> f64 {
        let len = items.len();
        if len == 0 {
            return 0.0;
        }
        if self.threads == 1 || len == 1 {
            // Inline on the caller: its own CPU clock sees the work.
            for (i, item) in items.iter_mut().enumerate() {
                f(i, item);
                complete(i, item);
            }
            return 0.0;
        }
        let workers = self.threads.min(len);
        // Hand-off queue: each `&mut` item is parked in a mutex slot,
        // claimed by exactly one worker (unique `next` index), and sent
        // back to the caller through the channel once `f` ran. The mutex
        // only transfers ownership of the borrow; items are never shared.
        let next = std::sync::atomic::AtomicUsize::new(0);
        let slots: Vec<std::sync::Mutex<Option<&mut T>>> =
            items.iter_mut().map(|it| std::sync::Mutex::new(Some(it))).collect();
        let (tx, rx) = std::sync::mpsc::channel::<(usize, &mut T)>();
        let mut cpu: Vec<f64> = vec![0.0; workers];
        std::thread::scope(|s| {
            let f = &f;
            let next = &next;
            let slots = &slots;
            for cpu_slot in cpu.iter_mut() {
                let tx = tx.clone();
                s.spawn(move || {
                    let t = crate::util::timing::CpuTimer::start();
                    loop {
                        let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        if i >= len {
                            break;
                        }
                        let item = slots[i].lock().unwrap().take().expect("item claimed twice");
                        f(i, item);
                        // The caller outlives the scope; a send can only
                        // fail if the receiver was dropped by a panic.
                        if tx.send((i, item)).is_err() {
                            break;
                        }
                    }
                    *cpu_slot = t.elapsed_secs();
                });
            }
            drop(tx);
            // Stream completions as they land; ends when all worker
            // senders hung up (every item delivered or a worker died).
            while let Ok((i, item)) = rx.recv() {
                complete(i, item);
            }
        });
        cpu.into_iter().fold(0.0, f64::max)
    }

    /// Streaming parallel for-each — the receive-side mirror of
    /// [`for_each_mut_completion`](Self::for_each_mut_completion): there
    /// the *workers* produce and the **caller** consumes completions;
    /// here the **caller** produces work and the *workers* consume it.
    /// `produce` runs on the calling thread and hands out `(index,
    /// payload)` pairs through its `feed` argument as they become ready
    /// (e.g. a receive loop completing one source's wire at a time);
    /// pool workers pick each pair up immediately and run
    /// `f(index, payload, &mut items[index])` — so the first item's
    /// processing overlaps whatever the producer is still waiting on.
    /// This is the overlap primitive for the streaming aura ingest:
    /// decode workers race the receiving rank thread
    /// ([`Codec::decode_pooled_streamed`]).
    ///
    /// Contract: `produce` must feed every index in `0..items.len()`
    /// exactly once before returning. Item `i` is claimed by exactly one
    /// worker; per-index state stays deterministic for any thread count
    /// because each item only ever sees its own `(index, payload)` pair —
    /// scheduling moves *when* an item is processed, never *what* it
    /// computes. With one thread (or one item) each fed pair is processed
    /// inline on the caller the moment it is fed — the serial
    /// receive→process interleaving, with no queueing and no allocation
    /// (the multi-thread dispatch allocates bounded per-call scratch,
    /// like every other fan-out here).
    ///
    /// Returns `produce`'s result plus the workers' critical-path CPU
    /// seconds (see [`map_chunks_timed`](Self::map_chunks_timed); inline
    /// work is visible to the caller's own CPU clock and reported as 0).
    ///
    /// [`Codec::decode_pooled_streamed`]: crate::io::codec::Codec::decode_pooled_streamed
    pub fn for_each_mut_streamed<T: Send, W: Send, R>(
        &self,
        items: &mut [T],
        f: impl Fn(usize, W, &mut T) + Sync,
        produce: impl FnOnce(&mut dyn FnMut(usize, W)) -> R,
    ) -> (R, f64) {
        let len = items.len();
        if len == 0 {
            let r = produce(&mut |_, _| panic!("fed an index into an empty item set"));
            return (r, 0.0);
        }
        if self.threads == 1 || len == 1 {
            // Inline on the caller, immediately per fed pair: its own CPU
            // clock sees the work. Duplicate feeds are caught exactly in
            // debug builds (matching the threaded path's slot claim);
            // release builds keep the count check only, so the hot path
            // stays allocation-free.
            let mut fed = 0usize;
            #[cfg(debug_assertions)]
            let mut seen = vec![false; len];
            let r = {
                let f = &f;
                let fed = &mut fed;
                #[cfg(debug_assertions)]
                let seen = &mut seen;
                produce(&mut |i, w| {
                    #[cfg(debug_assertions)]
                    {
                        assert!(!seen[i], "index {i} fed twice");
                        seen[i] = true;
                    }
                    *fed += 1;
                    f(i, w, &mut items[i]);
                })
            };
            assert_eq!(fed, len, "produce must feed every index exactly once");
            return (r, 0.0);
        }
        let workers = self.threads.min(len);
        // Hand-off: each `&mut` item is parked in a mutex slot and
        // claimed by the worker that dequeues its index — the mutex only
        // transfers ownership of the borrow; items are never shared.
        let slots: Vec<std::sync::Mutex<Option<&mut T>>> =
            items.iter_mut().map(|it| std::sync::Mutex::new(Some(it))).collect();
        let (tx, rx) = std::sync::mpsc::channel::<(usize, W)>();
        let rx = std::sync::Mutex::new(rx);
        let mut cpu: Vec<f64> = vec![0.0; workers];
        let r = std::thread::scope(|s| {
            let f = &f;
            let slots = &slots;
            let rx = &rx;
            for cpu_slot in cpu.iter_mut() {
                s.spawn(move || {
                    let t = crate::util::timing::CpuTimer::start();
                    loop {
                        // The dequeue lock is held across the blocking
                        // recv — contending workers queue on the mutex,
                        // so hand-out stays serialized but processing
                        // (`f`) runs in parallel.
                        let msg = rx.lock().unwrap().recv();
                        match msg {
                            Ok((i, w)) => {
                                let item =
                                    slots[i].lock().unwrap().take().expect("index fed twice");
                                f(i, w, item);
                            }
                            Err(_) => break, // producer done, queue drained
                        }
                    }
                    *cpu_slot = t.elapsed_secs();
                });
            }
            let mut fed = 0usize;
            let r = {
                let fed = &mut fed;
                let mut feed = |i: usize, w: W| {
                    *fed += 1;
                    // A send fails only if every worker died; surface that
                    // as a panic at the producer rather than a silent drop.
                    tx.send((i, w)).expect("streamed pool workers gone");
                };
                produce(&mut feed)
            };
            // Under-feeding would return with items silently unprocessed;
            // duplicate feeds are caught by the slot claim in the workers.
            assert_eq!(fed, len, "produce must feed every index exactly once");
            drop(tx); // hang up: workers drain the queue and exit
            r
        });
        (r, cpu.into_iter().fold(0.0, f64::max))
    }

    /// Parallel for-each over mutable items: workers receive disjoint
    /// contiguous sub-slices of `items`, so per-item scratch (e.g. reused
    /// mechanics gather batches) can be mutated in place without locking.
    /// `f(item_index, &mut item)`. Returns the region's critical-path CPU
    /// seconds (see [`map_chunks_timed`](Self::map_chunks_timed)).
    pub fn for_each_mut_timed<T: Send>(
        &self,
        items: &mut [T],
        f: impl Fn(usize, &mut T) + Sync,
    ) -> f64 {
        let len = items.len();
        if len == 0 {
            return 0.0;
        }
        let chunk = len.div_ceil(self.threads.min(len));
        if chunk >= len {
            // Inline on the caller: its own CPU clock sees the work.
            for (i, item) in items.iter_mut().enumerate() {
                f(i, item);
            }
            return 0.0;
        }
        let mut cpu: Vec<f64> = vec![0.0; len.div_ceil(chunk)];
        std::thread::scope(|s| {
            let f = &f;
            for ((ci, sub), cpu_slot) in items.chunks_mut(chunk).enumerate().zip(cpu.iter_mut()) {
                s.spawn(move || {
                    let t = crate::util::timing::CpuTimer::start();
                    for (k, item) in sub.iter_mut().enumerate() {
                        f(ci * chunk + k, item);
                    }
                    *cpu_slot = t.elapsed_secs();
                });
            }
        });
        cpu.into_iter().fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn covers_all_indices_once() {
        let pool = ThreadPool::new(4);
        let hits = AtomicU64::new(0);
        let sum = AtomicU64::new(0);
        pool.for_chunks(1000, |_, s, e| {
            for i in s..e {
                hits.fetch_add(1, Ordering::Relaxed);
                sum.fetch_add(i as u64, Ordering::Relaxed);
            }
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1000);
        assert_eq!(sum.load(Ordering::Relaxed), 999 * 1000 / 2);
    }

    #[test]
    fn map_chunks_preserves_order() {
        let pool = ThreadPool::new(3);
        let parts = pool.map_chunks(10, |ci, s, e| (ci, s, e));
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[0], (0, 0, 4));
        assert_eq!(parts[1], (1, 4, 8));
        assert_eq!(parts[2], (2, 8, 10));
    }

    #[test]
    fn single_thread_runs_inline() {
        let pool = ThreadPool::new(1);
        let parts = pool.map_chunks(5, |_, s, e| e - s);
        assert_eq!(parts, vec![5]);
    }

    #[test]
    fn empty_range_is_noop() {
        let pool = ThreadPool::new(4);
        assert!(pool.map_chunks(0, |_, _, _| ()).is_empty());
    }

    #[test]
    fn more_threads_than_items() {
        let pool = ThreadPool::new(16);
        let parts = pool.map_chunks(3, |_, s, e| (s, e));
        assert_eq!(parts.len(), 3);
    }

    #[test]
    fn zero_threads_clamps_to_one() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.threads(), 1);
    }

    #[test]
    fn map_parts_respects_custom_boundaries() {
        let pool = ThreadPool::new(4);
        // Uneven, data-dependent boundaries with an empty middle part.
        let bounds = [0usize, 3, 3, 10, 11];
        let (parts, _) = pool.map_parts_timed(&bounds, |pi, s, e| (pi, s, e));
        assert_eq!(parts, vec![(0, 0, 3), (1, 3, 3), (2, 3, 10), (3, 10, 11)]);
        // Degenerate inputs.
        let (none, cpu) = pool.map_parts_timed(&[], |_, _, _| ());
        assert!(none.is_empty() && cpu == 0.0);
        let (one, _) = pool.map_parts_timed(&[2, 7], |_, s, e| e - s);
        assert_eq!(one, vec![5]);
    }

    #[test]
    fn for_each_mut_completion_produces_and_completes_every_item_once() {
        for threads in [1, 3, 16] {
            let pool = ThreadPool::new(threads);
            let mut items: Vec<(u64, u64)> = vec![(0, 0); 29];
            let mut completed = vec![false; 29];
            let mut order: Vec<usize> = Vec::new();
            pool.for_each_mut_completion(
                &mut items,
                |i, item| item.0 = i as u64 + 1,
                |i, item| {
                    assert_eq!(item.0, i as u64 + 1, "complete before produce");
                    item.1 = item.0 * 2;
                    assert!(!completed[i], "item {i} completed twice");
                    completed[i] = true;
                    order.push(i);
                },
            );
            for (i, item) in items.iter().enumerate() {
                assert_eq!(*item, (i as u64 + 1, (i as u64 + 1) * 2), "{threads} threads");
            }
            assert!(completed.iter().all(|&c| c), "{threads} threads: missing completion");
            assert_eq!(order.len(), 29);
            if threads == 1 {
                // Inline path: strict index order.
                assert!(order.windows(2).all(|w| w[0] < w[1]));
            }
        }
        // Empty input is a no-op.
        let pool = ThreadPool::new(4);
        let mut empty: Vec<u64> = Vec::new();
        assert_eq!(pool.for_each_mut_completion(&mut empty, |_, _| (), |_, _| ()), 0.0);
    }

    #[test]
    fn for_each_mut_streamed_processes_every_fed_item_once() {
        for threads in [1, 3, 16] {
            let pool = ThreadPool::new(threads);
            let mut items: Vec<(u64, u64)> = vec![(0, 0); 31];
            // Feed indices in a scrambled order with payloads that the
            // worker must pair with the right item.
            let order: Vec<usize> = (0..31).map(|i| (i * 7) % 31).collect();
            let (fed_count, _cpu) = pool.for_each_mut_streamed(
                &mut items,
                |i, payload: u64, item| {
                    assert_eq!(payload, i as u64 * 3, "payload routed to wrong item");
                    item.0 = payload;
                    item.1 = 1;
                },
                |feed| {
                    for &i in &order {
                        feed(i, i as u64 * 3);
                    }
                    order.len()
                },
            );
            assert_eq!(fed_count, 31);
            for (i, item) in items.iter().enumerate() {
                assert_eq!(*item, (i as u64 * 3, 1), "item {i} with {threads} threads");
            }
        }
    }

    #[test]
    fn for_each_mut_streamed_overlaps_processing_with_production() {
        // With real workers, an item fed early must be able to *finish*
        // while the producer is still running — proven by having the
        // producer wait for the first item's side effect.
        let pool = ThreadPool::new(4);
        let mut items = vec![0u8; 2];
        let done = AtomicU64::new(0);
        pool.for_each_mut_streamed(
            &mut items,
            |i, _: (), item| {
                *item = 1;
                done.fetch_add(1 << (i * 8), Ordering::SeqCst);
            },
            |feed| {
                feed(0, ());
                // The worker-side processing of item 0 completes while
                // this producer is still "receiving".
                while done.load(Ordering::SeqCst) & 0xFF == 0 {
                    std::thread::yield_now();
                }
                feed(1, ());
            },
        );
        assert_eq!(items, vec![1, 1]);
    }

    #[test]
    fn for_each_mut_streamed_empty_and_single() {
        let pool = ThreadPool::new(4);
        let mut empty: Vec<u8> = Vec::new();
        let (r, cpu) = pool.for_each_mut_streamed(&mut empty, |_, _: u8, _| (), |_| 42);
        assert_eq!((r, cpu), (42, 0.0));
        // One item runs deferred-inline on the caller.
        let mut one = vec![0u64];
        let (r, cpu) = pool.for_each_mut_streamed(
            &mut one,
            |_, w: u64, item| *item = w,
            |feed| {
                feed(0, 9);
                "done"
            },
        );
        assert_eq!((r, cpu), ("done", 0.0));
        assert_eq!(one[0], 9);
    }

    #[test]
    fn for_each_mut_visits_every_item_once() {
        for threads in [1, 3, 16] {
            let pool = ThreadPool::new(threads);
            let mut items: Vec<u64> = vec![0; 37];
            pool.for_each_mut_timed(&mut items, |i, item| {
                *item += i as u64 + 1;
            });
            for (i, item) in items.iter().enumerate() {
                assert_eq!(*item, i as u64 + 1, "item {i} with {threads} threads");
            }
        }
        // Empty input is a no-op.
        let pool = ThreadPool::new(4);
        let mut empty: Vec<u64> = Vec::new();
        assert_eq!(pool.for_each_mut_timed(&mut empty, |_, _| ()), 0.0);
    }
}
