//! The per-rank world view handed to models, plus the aura store.
//!
//! [`AuraStore`] keeps received aura messages in their zero-copy TA IO
//! form: neighbor attribute reads go straight into the receive buffers
//! (the paper's "agents accessed directly from the received buffer").
//! Only the ROOT IO baseline materializes owned copies.

use crate::core::agent::{Agent, AgentKind};
use crate::core::ids::LocalId;
use crate::core::resource_manager::ResourceManager;
use crate::io::codec::Decoded;
use crate::io::ta_io::TaView;
use crate::space::{Aabb, BoundaryCondition, NeighborSearchGrid, NsgEntry};
use crate::util::{Rng, Vec3};

/// Aura agents received this iteration, in zero-copy or owned form.
#[derive(Default)]
pub struct AuraStore {
    views: Vec<TaView>,
    owned: Vec<Vec<Agent>>,
    /// Flattened index: aura id -> (source index, slot, is_view).
    index: Vec<(u32, u32, bool)>,
}

impl AuraStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Drop all aura data (start of each iteration; the paper's
    /// rebuilt-every-iteration aura lifecycle).
    pub fn clear(&mut self) {
        self.views.clear();
        self.owned.clear();
        self.index.clear();
    }

    /// Ingest one decoded message; returns the flat aura ids assigned to
    /// its agents (placeholder-free by construction).
    pub fn add_source(&mut self, decoded: Decoded) -> std::ops::Range<u32> {
        let start = self.index.len() as u32;
        match decoded {
            Decoded::View(view) => {
                let src = self.views.len() as u32;
                for slot in 0..view.len() {
                    if !view.agent(slot).is_placeholder() {
                        self.index.push((src, slot as u32, true));
                    }
                }
                self.views.push(view);
            }
            Decoded::Owned(agents) => {
                let src = self.owned.len() as u32;
                for slot in 0..agents.len() {
                    self.index.push((src, slot as u32, false));
                }
                self.owned.push(agents);
            }
        }
        start..self.index.len() as u32
    }

    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    /// Position of aura agent `i` (zero-copy for TA IO sources).
    pub fn position(&self, i: u32) -> Vec3 {
        let (src, slot, is_view) = self.index[i as usize];
        if is_view {
            Vec3::from_array(self.views[src as usize].agent(slot as usize).position)
        } else {
            self.owned[src as usize][slot as usize].position
        }
    }

    pub fn diameter(&self, i: u32) -> f64 {
        let (src, slot, is_view) = self.index[i as usize];
        if is_view {
            self.views[src as usize].agent(slot as usize).diameter
        } else {
            self.owned[src as usize][slot as usize].diameter
        }
    }

    pub fn kind(&self, i: u32) -> AgentKind {
        let (src, slot, is_view) = self.index[i as usize];
        if is_view {
            self.views[src as usize].agent(slot as usize).kind()
        } else {
            self.owned[src as usize][slot as usize].kind
        }
    }

    /// Bytes held by the aura buffers (memory accounting).
    pub fn approx_bytes(&self) -> u64 {
        let views: usize = self.views.iter().map(|v| v.buffer_bytes()).sum();
        let owned: usize = self
            .owned
            .iter()
            .map(|v| v.len() * std::mem::size_of::<Agent>())
            .sum();
        (views + owned + self.index.len() * 12) as u64
    }
}

/// Read-only neighbor record produced by [`World::neighbors_of`].
#[derive(Clone, Copy, Debug)]
pub struct NeighborInfo {
    pub pos: Vec3,
    pub diameter: f64,
    pub kind: AgentKind,
    /// Squared distance from the query center.
    pub dist_sq: f64,
}

/// The per-rank world handed to `Model::step`.
pub struct World<'a> {
    pub rank: u32,
    pub iteration: u64,
    pub rm: &'a mut ResourceManager,
    pub nsg: &'a mut NeighborSearchGrid,
    pub aura: &'a AuraStore,
    pub rng: &'a mut Rng,
    pub whole: Aabb,
    pub boundary: BoundaryCondition,
    pub interaction_radius: f64,
    /// Agents queued for creation (applied after the model step).
    pub spawns: Vec<Agent>,
    /// Agents queued for removal.
    pub removals: Vec<LocalId>,
    /// Intra-rank thread pool (the paper's OpenMP parallelism): models use
    /// [`World::par_chunks`] for read-only phases.
    pub pool: crate::engine::pool::ThreadPool,
    /// Critical-path CPU seconds of pool regions run by the model (f64
    /// bits; atomic so read-only parallel closures can stay `Sync`).
    pool_cpu_bits: std::sync::atomic::AtomicU64,
}

impl<'a> World<'a> {
    /// Construct a world view (engine-internal).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        rank: u32,
        iteration: u64,
        rm: &'a mut ResourceManager,
        nsg: &'a mut NeighborSearchGrid,
        aura: &'a AuraStore,
        rng: &'a mut Rng,
        whole: Aabb,
        boundary: BoundaryCondition,
        interaction_radius: f64,
        pool: crate::engine::pool::ThreadPool,
    ) -> Self {
        World {
            rank,
            iteration,
            rm,
            nsg,
            aura,
            rng,
            whole,
            boundary,
            interaction_radius,
            spawns: Vec::new(),
            removals: Vec::new(),
            pool,
            pool_cpu_bits: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// Read-only fork-join over `0..len` using the rank's thread pool;
    /// `f(chunk, start, end, &World)`. The region's critical-path CPU is
    /// recorded for the engine's parallel-runtime model.
    pub fn par_chunks<R: Send>(
        &self,
        len: usize,
        f: impl Fn(usize, usize, usize, &World) -> R + Sync,
    ) -> Vec<R> {
        let (out, cpu) = self.pool.map_chunks_timed(len, |c, s, e| f(c, s, e, self));
        let bits = self.pool_cpu_bits.load(std::sync::atomic::Ordering::Relaxed);
        let acc = f64::from_bits(bits) + cpu;
        self.pool_cpu_bits
            .store(acc.to_bits(), std::sync::atomic::Ordering::Relaxed);
        out
    }

    /// Pool CPU charged by the model through [`World::par_chunks`].
    pub fn take_pool_cpu(&self) -> f64 {
        let bits = self
            .pool_cpu_bits
            .swap(0, std::sync::atomic::Ordering::Relaxed);
        f64::from_bits(bits)
    }
    /// Neighbor records within `radius` of `center`, excluding `exclude`.
    /// Results are sorted by distance (then position) so iteration order
    /// is deterministic regardless of rank count or NSG layout.
    pub fn neighbors_of(&self, center: Vec3, radius: f64, exclude: Option<LocalId>) -> Vec<NeighborInfo> {
        let mut out = Vec::new();
        let ex = exclude.map(NsgEntry::Owned);
        self.nsg.for_each_neighbor(center, radius, ex, |entry, pos, d2| {
            let info = match entry {
                // Owned attributes come from the SoA mirror: the NSG handle
                // protocol guarantees the entry is live, so the column read
                // is branch-free and streams contiguous memory.
                NsgEntry::Owned(id) => {
                    debug_assert!(self.rm.get(id).is_some(), "NSG entry points at freed agent");
                    NeighborInfo {
                        pos,
                        diameter: self.rm.col_diameter(id.index),
                        kind: self.rm.col_kind(id.index),
                        dist_sq: d2,
                    }
                }
                NsgEntry::Aura(i) => NeighborInfo {
                    pos,
                    diameter: self.aura.diameter(i),
                    kind: self.aura.kind(i),
                    dist_sq: d2,
                },
            };
            out.push(info);
        });
        out.sort_by(|a, b| {
            a.dist_sq
                .partial_cmp(&b.dist_sq)
                .unwrap()
                .then(a.pos.x.partial_cmp(&b.pos.x).unwrap())
                .then(a.pos.y.partial_cmp(&b.pos.y).unwrap())
                .then(a.pos.z.partial_cmp(&b.pos.z).unwrap())
        });
        out
    }

    /// Count neighbors satisfying a predicate (no allocation).
    pub fn count_neighbors_where(
        &self,
        center: Vec3,
        radius: f64,
        exclude: Option<LocalId>,
        mut pred: impl FnMut(&AgentKind) -> bool,
    ) -> usize {
        let mut n = 0;
        let ex = exclude.map(NsgEntry::Owned);
        self.nsg.for_each_neighbor(center, radius, ex, |entry, _, _| {
            let kind = match entry {
                NsgEntry::Owned(id) => {
                    debug_assert!(self.rm.get(id).is_some(), "NSG entry points at freed agent");
                    self.rm.col_kind(id.index)
                }
                NsgEntry::Aura(i) => self.aura.kind(i),
            };
            if pred(&kind) {
                n += 1;
            }
        });
        n
    }

    /// Move an owned agent, applying the boundary condition and updating
    /// the NSG incrementally.
    pub fn move_agent(&mut self, id: LocalId, new_pos: Vec3) {
        let pos = self.boundary.apply(new_pos, &self.whole);
        if self.rm.set_position(id, pos) {
            self.nsg.update_position(NsgEntry::Owned(id), pos);
        }
    }

    /// Queue a spawn (applied by the engine after the model step).
    pub fn spawn(&mut self, mut agent: Agent) {
        agent.position = self.boundary.apply(agent.position, &self.whole);
        self.spawns.push(agent);
    }

    /// Queue a removal.
    pub fn remove(&mut self, id: LocalId) {
        self.removals.push(id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::agent::{CellType, SirState};
    use crate::core::ids::GlobalId;
    use crate::io::ta_io;

    fn aura_from_agents(agents: &[Agent]) -> AuraStore {
        let mut store = AuraStore::new();
        let buf = ta_io::serialize(agents.iter());
        let view = ta_io::TaView::parse(buf).unwrap();
        store.add_source(Decoded::View(view));
        store
    }

    #[test]
    fn aura_store_zero_copy_reads() {
        let mut a = Agent::cell(Vec3::new(1.0, 2.0, 3.0), 7.0, CellType::B);
        a.global_id = GlobalId::new(1, 1);
        let mut b = Agent::person(Vec3::new(4.0, 5.0, 6.0), SirState::Infected);
        b.global_id = GlobalId::new(1, 2);
        let store = aura_from_agents(&[a, b]);
        assert_eq!(store.len(), 2);
        assert_eq!(store.position(0), Vec3::new(1.0, 2.0, 3.0));
        assert_eq!(store.diameter(0), 7.0);
        assert!(matches!(store.kind(1), AgentKind::Person { state: SirState::Infected, .. }));
        assert!(store.approx_bytes() > 0);
    }

    #[test]
    fn aura_store_owned_path() {
        let mut store = AuraStore::new();
        let a = Agent::cell(Vec3::new(9.0, 9.0, 9.0), 2.0, CellType::A);
        store.add_source(Decoded::Owned(vec![a]));
        assert_eq!(store.len(), 1);
        assert_eq!(store.position(0), Vec3::new(9.0, 9.0, 9.0));
        store.clear();
        assert!(store.is_empty());
    }

    #[test]
    fn world_neighbor_query_merges_owned_and_aura() {
        let mut rm = ResourceManager::new(0);
        let whole = Aabb::cube(50.0);
        let mut nsg = NeighborSearchGrid::new(whole, 10.0);
        let id = rm.add(Agent::cell(Vec3::ZERO, 5.0, CellType::A));
        nsg.add(NsgEntry::Owned(id), Vec3::ZERO);
        let near = rm.add(Agent::cell(Vec3::new(3.0, 0.0, 0.0), 5.0, CellType::B));
        nsg.add(NsgEntry::Owned(near), Vec3::new(3.0, 0.0, 0.0));
        let mut aura_agent = Agent::cell(Vec3::new(0.0, 4.0, 0.0), 6.0, CellType::A);
        aura_agent.global_id = GlobalId::new(1, 0);
        let aura = aura_from_agents(&[aura_agent]);
        nsg.add(NsgEntry::Aura(0), Vec3::new(0.0, 4.0, 0.0));
        let mut rng = Rng::new(1);
        let world = World::new(
            0,
            0,
            &mut rm,
            &mut nsg,
            &aura,
            &mut rng,
            whole,
            BoundaryCondition::Closed,
            10.0,
            crate::engine::pool::ThreadPool::new(2),
        );
        let n = world.neighbors_of(Vec3::ZERO, 10.0, Some(id));
        assert_eq!(n.len(), 2);
        // Sorted by distance: owned at 3.0 first, aura at 4.0 second.
        assert_eq!(n[0].pos, Vec3::new(3.0, 0.0, 0.0));
        assert_eq!(n[1].diameter, 6.0);
        let count = world.count_neighbors_where(Vec3::ZERO, 10.0, Some(id), |k| {
            matches!(k, AgentKind::Cell { cell_type: CellType::A, .. })
        });
        assert_eq!(count, 1);
    }

    #[test]
    fn move_agent_applies_boundary_and_updates_nsg() {
        let mut rm = ResourceManager::new(0);
        let whole = Aabb::cube(10.0);
        let mut nsg = NeighborSearchGrid::new(whole, 5.0);
        let id = rm.add(Agent::cell(Vec3::ZERO, 1.0, CellType::A));
        nsg.add(NsgEntry::Owned(id), Vec3::ZERO);
        let aura = AuraStore::new();
        let mut rng = Rng::new(1);
        let mut world = World::new(
            0,
            0,
            &mut rm,
            &mut nsg,
            &aura,
            &mut rng,
            whole,
            BoundaryCondition::Closed,
            5.0,
            crate::engine::pool::ThreadPool::new(2),
        );
        world.move_agent(id, Vec3::new(100.0, 0.0, 0.0)); // clamps to edge
        let pos = world.rm.get(id).unwrap().position;
        assert!(pos.x < 10.0 && pos.x > 9.99);
        // NSG reflects the new position.
        let found = world.nsg.neighbors_of(pos, 0.01, None);
        assert_eq!(found.len(), 1);
    }
}
