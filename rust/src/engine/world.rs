//! The per-rank world view handed to models, plus the aura store.
//!
//! [`AuraStore`] keeps received aura messages alive in their zero-copy
//! TA IO form (the paper's "agents accessed directly from the received
//! buffer") and, at ingest, mirrors the three hot attributes —
//! position, diameter, kind — into flat SoA columns read straight out of
//! the receive buffer. Neighbor loops then stream aura agents exactly
//! like owned ones: a contiguous column read instead of a per-entry
//! `(source, slot, is_view)` indirection plus an enum decode per access.
//! Only the ROOT IO baseline materializes owned copies.
//!
//! [`World`] is the façade models program against: neighbor queries
//! resolve through the Morton-indexed NSG and read attributes from the
//! `ResourceManager` / [`AuraStore`] SoA columns, mutations go through
//! spawn/removal queues and the boundary-applying
//! [`World::move_agent`], and read-only phases can fork-join on the
//! rank's pool via [`World::par_chunks`] (results are deterministic for
//! any thread count — see `ARCHITECTURE.md`, "Determinism contract").

use crate::core::agent::{Agent, AgentBatch, AgentKind, Behavior, CellType};
use crate::core::ids::LocalId;
use crate::core::resource_manager::ResourceManager;
use crate::io::codec::Decoded;
use crate::io::ta_io::{TaView, ViewPool};
use crate::space::{Aabb, BoundaryCondition, NeighborSearchGrid, NsgEntry};
use crate::util::{Rng, Vec3};

/// Aura agents received this iteration: the live receive buffers plus
/// flat hot-attribute columns indexed by aura id.
#[derive(Default)]
pub struct AuraStore {
    /// Receive buffers kept alive for the iteration (in-buffer storage).
    views: Vec<TaView>,
    /// Owned agent batches from the ROOT IO baseline path.
    owned: Vec<AgentBatch>,
    /// Flat SoA mirror of the hot attributes, one entry per aura agent.
    pos: Vec<Vec3>,
    diam: Vec<f64>,
    kind: Vec<AgentKind>,
}

impl AuraStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Drop all aura data (start of each iteration; the paper's
    /// rebuilt-every-iteration aura lifecycle). Column capacity is kept;
    /// view buffers are freed — prefer [`AuraStore::recycle_into`] on the
    /// hot path so they return to the decode pool instead.
    pub fn clear(&mut self) {
        self.views.clear();
        self.owned.clear();
        self.pos.clear();
        self.diam.clear();
        self.kind.clear();
    }

    /// [`AuraStore::clear`], recycling the spent receive buffers into the
    /// decode pool — the steady state moves buffers in a closed loop
    /// (pool → decode → aura → pool) and allocates nothing.
    pub fn recycle_into(&mut self, pool: &mut ViewPool) {
        for view in self.views.drain(..) {
            pool.put_view(view);
        }
        self.owned.clear();
        self.pos.clear();
        self.diam.clear();
        self.kind.clear();
    }

    /// Ingest one decoded message; returns the flat aura ids assigned to
    /// its agents (placeholder-free by construction). Hot attributes are
    /// mirrored into the SoA columns directly from the receive buffer —
    /// no `Agent` is materialized.
    pub fn add_source(&mut self, decoded: Decoded) -> std::ops::Range<u32> {
        let start = self.pos.len() as u32;
        match decoded {
            Decoded::View(view) => {
                self.pos.reserve(view.len());
                self.diam.reserve(view.len());
                self.kind.reserve(view.len());
                for slot in 0..view.len() {
                    let ab = view.agent(slot);
                    if !ab.is_placeholder() {
                        self.pos.push(Vec3::from_array(ab.position));
                        self.diam.push(ab.diameter);
                        self.kind.push(ab.kind());
                    }
                }
                self.views.push(view);
            }
            Decoded::Owned(batch) => {
                self.pos.reserve(batch.len());
                self.diam.reserve(batch.len());
                self.kind.reserve(batch.len());
                for a in &batch.agents {
                    self.pos.push(a.position);
                    self.diam.push(a.diameter);
                    self.kind.push(a.kind);
                }
                self.owned.push(batch);
            }
        }
        start..self.pos.len() as u32
    }

    /// Ingest a whole iteration's decoded messages at once (drained from
    /// `decoded`, which must be in **source order** — the engine's
    /// neighbor-rank order). Ranges are assigned by prefix sums over the
    /// decoded agent counts before any mirroring happens, so aura-id
    /// assignment is deterministic regardless of the order the wires
    /// *arrived* in and of the thread count; `out_ranges[k]` is exactly
    /// what a serial [`AuraStore::add_source`] loop would have returned
    /// for `decoded[k]`. The hot-attribute mirror then fans out on the
    /// rank's pool, each source writing its own pre-reserved column
    /// window (disjoint `split_at_mut` slices — no locks). Returns the
    /// fan-out's critical-path CPU seconds.
    pub fn add_sources(
        &mut self,
        decoded: &mut Vec<Decoded>,
        pool: &crate::engine::pool::ThreadPool,
        out_ranges: &mut Vec<std::ops::Range<u32>>,
    ) -> f64 {
        out_ranges.clear();
        let start = self.pos.len();
        // Sizes straight from the decoded headers: the parse walk already
        // counted live (non-placeholder) agents, so range assignment is
        // O(sources), not a second pass over every agent block.
        let mut total = start;
        for d in decoded.iter() {
            let n = match d {
                Decoded::View(v) => v.live_len(),
                Decoded::Owned(a) => a.len(),
            };
            out_ranges.push(total as u32..(total + n) as u32);
            total += n;
        }
        // Every slot below `total` is overwritten by exactly one mirror
        // job; the fill value is never observable.
        const FILL_KIND: AgentKind = AgentKind::Cell { cell_type: CellType::A, adhesion: 0.0 };
        self.pos.resize(total, Vec3::ZERO);
        self.diam.resize(total, 0.0);
        self.kind.resize(total, FILL_KIND);
        struct MirrorJob<'a> {
            src: &'a Decoded,
            pos: &'a mut [Vec3],
            diam: &'a mut [f64],
            kind: &'a mut [AgentKind],
        }
        let mut pos_rest: &mut [Vec3] = &mut self.pos[start..];
        let mut diam_rest: &mut [f64] = &mut self.diam[start..];
        let mut kind_rest: &mut [AgentKind] = &mut self.kind[start..];
        let mut jobs: Vec<MirrorJob<'_>> = Vec::with_capacity(decoded.len());
        for (d, r) in decoded.iter().zip(out_ranges.iter()) {
            let n = (r.end - r.start) as usize;
            let (p, pr) = std::mem::take(&mut pos_rest).split_at_mut(n);
            let (dm, dr) = std::mem::take(&mut diam_rest).split_at_mut(n);
            let (kd, kr) = std::mem::take(&mut kind_rest).split_at_mut(n);
            pos_rest = pr;
            diam_rest = dr;
            kind_rest = kr;
            jobs.push(MirrorJob { src: d, pos: p, diam: dm, kind: kd });
        }
        let cpu = pool.for_each_mut_timed(&mut jobs, |_, j| {
            let mut w = 0;
            match j.src {
                Decoded::View(v) => {
                    for i in 0..v.len() {
                        let ab = v.agent(i);
                        if ab.is_placeholder() {
                            continue;
                        }
                        j.pos[w] = Vec3::from_array(ab.position);
                        j.diam[w] = ab.diameter;
                        j.kind[w] = ab.kind();
                        w += 1;
                    }
                }
                Decoded::Owned(batch) => {
                    for a in &batch.agents {
                        j.pos[w] = a.position;
                        j.diam[w] = a.diameter;
                        j.kind[w] = a.kind;
                        w += 1;
                    }
                }
            }
            debug_assert_eq!(w, j.pos.len(), "pre-reserved range mismatch");
        });
        drop(jobs);
        // Keep the receive buffers alive for the iteration, source order.
        for d in decoded.drain(..) {
            match d {
                Decoded::View(v) => self.views.push(v),
                Decoded::Owned(a) => self.owned.push(a),
            }
        }
        cpu
    }

    pub fn len(&self) -> usize {
        self.pos.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pos.is_empty()
    }

    /// The full position column (flat, indexed by aura id) — what the
    /// NSG's bulk aura registration streams.
    #[inline]
    pub fn positions(&self) -> &[Vec3] {
        &self.pos
    }

    /// Position of aura agent `i` (flat column read).
    #[inline]
    pub fn position(&self, i: u32) -> Vec3 {
        self.pos[i as usize]
    }

    #[inline]
    pub fn diameter(&self, i: u32) -> f64 {
        self.diam[i as usize]
    }

    #[inline]
    pub fn kind(&self, i: u32) -> AgentKind {
        self.kind[i as usize]
    }

    /// Bytes held by the aura buffers + columns (memory accounting).
    pub fn approx_bytes(&self) -> u64 {
        let views: usize = self.views.iter().map(|v| v.buffer_bytes()).sum();
        let owned: usize = self
            .owned
            .iter()
            .map(|b| {
                b.len() * std::mem::size_of::<Agent>()
                    + b.behavior_count() * std::mem::size_of::<Behavior>()
            })
            .sum();
        let cols = self.pos.capacity() * std::mem::size_of::<Vec3>()
            + self.diam.capacity() * 8
            + self.kind.capacity() * std::mem::size_of::<AgentKind>();
        (views + owned + cols) as u64
    }
}

/// Read-only neighbor record produced by [`World::neighbors_of`].
#[derive(Clone, Copy, Debug)]
pub struct NeighborInfo {
    pub pos: Vec3,
    pub diameter: f64,
    pub kind: AgentKind,
    /// Squared distance from the query center.
    pub dist_sq: f64,
}

/// The per-rank world handed to `Model::step`.
pub struct World<'a> {
    pub rank: u32,
    pub iteration: u64,
    pub rm: &'a mut ResourceManager,
    pub nsg: &'a mut NeighborSearchGrid,
    pub aura: &'a AuraStore,
    pub rng: &'a mut Rng,
    pub whole: Aabb,
    pub boundary: BoundaryCondition,
    pub interaction_radius: f64,
    /// Agents queued for creation, each with its behavior set (applied
    /// after the model step).
    pub spawns: AgentBatch,
    /// Agents queued for removal.
    pub removals: Vec<LocalId>,
    /// Intra-rank thread pool (the paper's OpenMP parallelism): models use
    /// [`World::par_chunks`] for read-only phases.
    pub pool: crate::engine::pool::ThreadPool,
    /// Critical-path CPU seconds of pool regions run by the model (f64
    /// bits; atomic so read-only parallel closures can stay `Sync`).
    pool_cpu_bits: std::sync::atomic::AtomicU64,
}

impl<'a> World<'a> {
    /// Construct a world view (engine-internal).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        rank: u32,
        iteration: u64,
        rm: &'a mut ResourceManager,
        nsg: &'a mut NeighborSearchGrid,
        aura: &'a AuraStore,
        rng: &'a mut Rng,
        whole: Aabb,
        boundary: BoundaryCondition,
        interaction_radius: f64,
        pool: crate::engine::pool::ThreadPool,
    ) -> Self {
        World {
            rank,
            iteration,
            rm,
            nsg,
            aura,
            rng,
            whole,
            boundary,
            interaction_radius,
            spawns: AgentBatch::new(),
            removals: Vec::new(),
            pool,
            pool_cpu_bits: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// Read-only fork-join over `0..len` using the rank's thread pool;
    /// `f(chunk, start, end, &World)`. The region's critical-path CPU is
    /// recorded for the engine's parallel-runtime model.
    pub fn par_chunks<R: Send>(
        &self,
        len: usize,
        f: impl Fn(usize, usize, usize, &World) -> R + Sync,
    ) -> Vec<R> {
        let (out, cpu) = self.pool.map_chunks_timed(len, |c, s, e| f(c, s, e, self));
        let bits = self.pool_cpu_bits.load(std::sync::atomic::Ordering::Relaxed);
        let acc = f64::from_bits(bits) + cpu;
        self.pool_cpu_bits
            .store(acc.to_bits(), std::sync::atomic::Ordering::Relaxed);
        out
    }

    /// Pool CPU charged by the model through [`World::par_chunks`].
    pub fn take_pool_cpu(&self) -> f64 {
        let bits = self
            .pool_cpu_bits
            .swap(0, std::sync::atomic::Ordering::Relaxed);
        f64::from_bits(bits)
    }
    /// Neighbor records within `radius` of `center`, excluding `exclude`.
    /// Results are sorted by distance (then position) so iteration order
    /// is deterministic regardless of rank count or NSG layout.
    pub fn neighbors_of(&self, center: Vec3, radius: f64, exclude: Option<LocalId>) -> Vec<NeighborInfo> {
        let mut out = Vec::new();
        let ex = exclude.map(NsgEntry::Owned);
        self.nsg.for_each_neighbor(center, radius, ex, |entry, pos, d2| {
            let info = match entry {
                // Owned attributes come from the SoA mirror: the NSG handle
                // protocol guarantees the entry is live, so the column read
                // is branch-free and streams contiguous memory.
                NsgEntry::Owned(id) => {
                    debug_assert!(self.rm.get(id).is_some(), "NSG entry points at freed agent");
                    NeighborInfo {
                        pos,
                        diameter: self.rm.col_diameter(id.index),
                        kind: self.rm.col_kind(id.index),
                        dist_sq: d2,
                    }
                }
                NsgEntry::Aura(i) => NeighborInfo {
                    pos,
                    diameter: self.aura.diameter(i),
                    kind: self.aura.kind(i),
                    dist_sq: d2,
                },
            };
            out.push(info);
        });
        out.sort_by(|a, b| {
            a.dist_sq
                .partial_cmp(&b.dist_sq)
                .unwrap()
                .then(a.pos.x.partial_cmp(&b.pos.x).unwrap())
                .then(a.pos.y.partial_cmp(&b.pos.y).unwrap())
                .then(a.pos.z.partial_cmp(&b.pos.z).unwrap())
        });
        out
    }

    /// Count neighbors satisfying a predicate (no allocation).
    pub fn count_neighbors_where(
        &self,
        center: Vec3,
        radius: f64,
        exclude: Option<LocalId>,
        mut pred: impl FnMut(&AgentKind) -> bool,
    ) -> usize {
        let mut n = 0;
        let ex = exclude.map(NsgEntry::Owned);
        self.nsg.for_each_neighbor(center, radius, ex, |entry, _, _| {
            let kind = match entry {
                NsgEntry::Owned(id) => {
                    debug_assert!(self.rm.get(id).is_some(), "NSG entry points at freed agent");
                    self.rm.col_kind(id.index)
                }
                NsgEntry::Aura(i) => self.aura.kind(i),
            };
            if pred(&kind) {
                n += 1;
            }
        });
        n
    }

    /// Move an owned agent, applying the boundary condition and updating
    /// the NSG incrementally.
    pub fn move_agent(&mut self, id: LocalId, new_pos: Vec3) {
        let pos = self.boundary.apply(new_pos, &self.whole);
        if self.rm.set_position(id, pos) {
            self.nsg.update_position(NsgEntry::Owned(id), pos);
        }
    }

    /// Queue a behavior-less spawn (applied by the engine after the
    /// model step).
    pub fn spawn(&mut self, agent: Agent) {
        self.spawn_with(agent, &[]);
    }

    /// Queue a spawn carrying an initial behavior set; the behaviors land
    /// in the store's arena when the engine applies the queue.
    pub fn spawn_with(&mut self, mut agent: Agent, behaviors: &[Behavior]) {
        agent.position = self.boundary.apply(agent.position, &self.whole);
        self.spawns.push(agent, behaviors);
    }

    /// Queue a removal.
    pub fn remove(&mut self, id: LocalId) {
        self.removals.push(id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::agent::{CellType, SirState};
    use crate::core::ids::GlobalId;
    use crate::io::ta_io;

    fn aura_from_agents(agents: &[Agent]) -> AuraStore {
        let mut store = AuraStore::new();
        let buf = ta_io::serialize(agents.iter());
        let view = ta_io::TaView::parse(buf).unwrap();
        store.add_source(Decoded::View(view));
        store
    }

    #[test]
    fn aura_store_zero_copy_reads() {
        let mut a = Agent::cell(Vec3::new(1.0, 2.0, 3.0), 7.0, CellType::B);
        a.global_id = GlobalId::new(1, 1);
        let mut b = Agent::person(Vec3::new(4.0, 5.0, 6.0), SirState::Infected);
        b.global_id = GlobalId::new(1, 2);
        let store = aura_from_agents(&[a, b]);
        assert_eq!(store.len(), 2);
        assert_eq!(store.position(0), Vec3::new(1.0, 2.0, 3.0));
        assert_eq!(store.diameter(0), 7.0);
        assert!(matches!(store.kind(1), AgentKind::Person { state: SirState::Infected, .. }));
        assert!(store.approx_bytes() > 0);
    }

    #[test]
    fn aura_store_recycles_buffers_to_pool() {
        let mut a = Agent::cell(Vec3::new(1.0, 2.0, 3.0), 7.0, CellType::B);
        a.global_id = GlobalId::new(1, 1);
        let mut store = aura_from_agents(&[a]);
        assert_eq!(store.len(), 1);
        let mut pool = crate::io::ta_io::ViewPool::new();
        store.recycle_into(&mut pool);
        assert!(store.is_empty());
        assert!(pool.approx_bytes() > 0, "buffers must land in the pool");
    }

    #[test]
    fn aura_store_owned_path() {
        let mut store = AuraStore::new();
        let a = Agent::cell(Vec3::new(9.0, 9.0, 9.0), 2.0, CellType::A);
        store.add_source(Decoded::Owned(AgentBatch::from_agents(vec![a])));
        assert_eq!(store.len(), 1);
        assert_eq!(store.position(0), Vec3::new(9.0, 9.0, 9.0));
        store.clear();
        assert!(store.is_empty());
    }

    #[test]
    fn add_sources_matches_serial_add_source_at_any_thread_count() {
        use crate::engine::pool::ThreadPool;
        use crate::util::Rng;
        let mut rng = Rng::new(0xA0A0);
        let pops: Vec<Vec<Agent>> = (0..4)
            .map(|k| {
                (0..30 + 11 * k)
                    .map(|i| {
                        let mut a = Agent::cell(
                            Vec3::from_array(rng.point_in([0.0; 3], [50.0; 3])),
                            4.0 + i as f64 * 0.01,
                            if i % 2 == 0 { CellType::A } else { CellType::B },
                        );
                        a.global_id = GlobalId::new(k as u32 + 1, i as u64);
                        a
                    })
                    .collect()
            })
            .collect();
        let mk_decoded = || -> Vec<Decoded> {
            pops.iter()
                .map(|p| {
                    Decoded::View(ta_io::TaView::parse(ta_io::serialize(p.iter())).unwrap())
                })
                .collect()
        };
        // Serial oracle.
        let mut serial = AuraStore::new();
        let want_ranges: Vec<std::ops::Range<u32>> =
            mk_decoded().into_iter().map(|d| serial.add_source(d)).collect();
        for threads in [1usize, 2, 8] {
            let pool = ThreadPool::new(threads);
            let mut bulk = AuraStore::new();
            let mut decoded = mk_decoded();
            let mut ranges = Vec::new();
            bulk.add_sources(&mut decoded, &pool, &mut ranges);
            assert!(decoded.is_empty(), "decoded views must be consumed");
            assert_eq!(ranges, want_ranges, "{threads} threads: aura id ranges");
            assert_eq!(bulk.len(), serial.len());
            for i in 0..bulk.len() as u32 {
                assert_eq!(bulk.position(i), serial.position(i), "{threads} threads, aura {i}");
                assert_eq!(bulk.diameter(i), serial.diameter(i));
                assert_eq!(bulk.kind(i), serial.kind(i));
            }
        }
    }

    #[test]
    fn world_neighbor_query_merges_owned_and_aura() {
        let mut rm = ResourceManager::new(0);
        let whole = Aabb::cube(50.0);
        let mut nsg = NeighborSearchGrid::new(whole, 10.0);
        let id = rm.add(Agent::cell(Vec3::ZERO, 5.0, CellType::A));
        nsg.add(NsgEntry::Owned(id), Vec3::ZERO);
        let near = rm.add(Agent::cell(Vec3::new(3.0, 0.0, 0.0), 5.0, CellType::B));
        nsg.add(NsgEntry::Owned(near), Vec3::new(3.0, 0.0, 0.0));
        let mut aura_agent = Agent::cell(Vec3::new(0.0, 4.0, 0.0), 6.0, CellType::A);
        aura_agent.global_id = GlobalId::new(1, 0);
        let aura = aura_from_agents(&[aura_agent]);
        nsg.add(NsgEntry::Aura(0), Vec3::new(0.0, 4.0, 0.0));
        let mut rng = Rng::new(1);
        let world = World::new(
            0,
            0,
            &mut rm,
            &mut nsg,
            &aura,
            &mut rng,
            whole,
            BoundaryCondition::Closed,
            10.0,
            crate::engine::pool::ThreadPool::new(2),
        );
        let n = world.neighbors_of(Vec3::ZERO, 10.0, Some(id));
        assert_eq!(n.len(), 2);
        // Sorted by distance: owned at 3.0 first, aura at 4.0 second.
        assert_eq!(n[0].pos, Vec3::new(3.0, 0.0, 0.0));
        assert_eq!(n[1].diameter, 6.0);
        let count = world.count_neighbors_where(Vec3::ZERO, 10.0, Some(id), |k| {
            matches!(k, AgentKind::Cell { cell_type: CellType::A, .. })
        });
        assert_eq!(count, 1);
    }

    #[test]
    fn move_agent_applies_boundary_and_updates_nsg() {
        let mut rm = ResourceManager::new(0);
        let whole = Aabb::cube(10.0);
        let mut nsg = NeighborSearchGrid::new(whole, 5.0);
        let id = rm.add(Agent::cell(Vec3::ZERO, 1.0, CellType::A));
        nsg.add(NsgEntry::Owned(id), Vec3::ZERO);
        let aura = AuraStore::new();
        let mut rng = Rng::new(1);
        let mut world = World::new(
            0,
            0,
            &mut rm,
            &mut nsg,
            &aura,
            &mut rng,
            whole,
            BoundaryCondition::Closed,
            5.0,
            crate::engine::pool::ThreadPool::new(2),
        );
        world.move_agent(id, Vec3::new(100.0, 0.0, 0.0)); // clamps to edge
        let pos = world.rm.get(id).unwrap().position;
        assert!(pos.x < 10.0 && pos.x > 9.99);
        // NSG reflects the new position.
        let found = world.nsg.neighbors_of(pos, 0.01, None);
        assert_eq!(found.len(), 1);
    }
}
