//! The `Model` trait: what a simulation author writes.
//!
//! Mirrors the paper's user-facing programming model (§3.4 "seamless
//! transition"): a model defines its agents, behaviors and statistics and
//! is *completely unaware of distribution* — the engine supplies aura
//! agents transparently through the neighbor queries, migrates agents, and
//! sums statistics across ranks (the paper's `SumOverAllRanks`).

use super::init::InitCtx;
use super::world::World;
use crate::core::agent::AgentKind;
use crate::runtime::MechanicsParams;

/// A simulation model. One instance per rank (construct via the factory
/// passed to [`run_simulation`](super::launcher::run_simulation)).
/// `Sync` is required because read-only hooks (`adhesion_scale`) are
/// called from the rank's thread pool during the mechanics gather.
pub trait Model: Send + Sync + 'static {
    fn name(&self) -> &'static str;

    /// Maximum interaction distance (sets the NSG cell and aura width).
    fn interaction_radius(&self) -> f64;

    /// Whether the engine should run the mechanical-force phase (the
    /// JAX/Pallas kernel) each iteration.
    fn uses_mechanics(&self) -> bool {
        true
    }

    fn mechanics_params(&self) -> MechanicsParams {
        MechanicsParams::default()
    }

    /// Per-pair adhesion scale in (0, 1]; 1.0 = full adhesion. This is the
    /// differential-adhesion hook behind the cell-sorting benchmark.
    fn adhesion_scale(&self, _a: &AgentKind, _b: &AgentKind) -> f32 {
        1.0
    }

    /// Create the initial agents (§2.4.4 distributed initialization: the
    /// context only keeps agents whose position this rank owns, so agents
    /// are born on their authoritative rank without a mass migration).
    fn create_agents(&self, ctx: &mut InitCtx);

    /// Model-specific per-iteration behaviors (growth, division,
    /// infection, …). Mechanics has already run when this is called.
    fn step(&mut self, world: &mut World);

    /// Rank-local statistics recorded at the end of each iteration. The
    /// launcher combines them across ranks via [`Model::combine_stats`].
    fn local_stats(&self, _world: &World) -> Vec<f64> {
        Vec::new()
    }

    /// Combine per-rank stats into the global record (default: sum).
    fn combine_stats(&self, per_rank: &[Vec<f64>]) -> Vec<f64> {
        let width = per_rank.iter().map(|v| v.len()).max().unwrap_or(0);
        let mut out = vec![0.0; width];
        for v in per_rank {
            for (i, x) in v.iter().enumerate() {
                out[i] += x;
            }
        }
        out
    }

    /// Names for the stat columns (reporting).
    fn stat_names(&self) -> Vec<&'static str> {
        Vec::new()
    }
}
