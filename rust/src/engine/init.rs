//! Distributed initialization (§2.4.4): create agents directly on their
//! authoritative rank, avoiding a mass migration after setup.
//!
//! The generator stream is seeded identically on every rank; each rank
//! keeps only the agents whose position it owns. This yields *bitwise
//! identical* initial conditions regardless of rank count — the property
//! the distributed-determinism tests rely on — while still creating every
//! agent on its authoritative rank. (The paper's volume-fraction
//! optimization for very large populations trades this identity for O(n/R)
//! generation time; see `scatter_uniform_fraction`.)

use crate::core::agent::Agent;
use crate::space::{Aabb, PartitionGrid};
use crate::util::{Rng, Vec3};

/// Initialization context handed to `Model::create_agents`.
pub struct InitCtx<'a> {
    pub rank: u32,
    pub whole: Aabb,
    grid: &'a PartitionGrid,
    rng: Rng,
    kept: Vec<Agent>,
    total_generated: u64,
}

impl<'a> InitCtx<'a> {
    pub fn new(rank: u32, grid: &'a PartitionGrid, seed: u64) -> Self {
        InitCtx {
            rank,
            whole: grid.whole(),
            grid,
            // Same stream on every rank — identity across rank counts.
            rng: Rng::stream(seed, 0xD157_0000),
            kept: Vec::new(),
            total_generated: 0,
        }
    }

    /// Generate `n` agents at uniform random positions in `region` via
    /// `make(position, rng)`; keep those owned by this rank.
    pub fn scatter_uniform(
        &mut self,
        n: usize,
        region: Aabb,
        mut make: impl FnMut(Vec3, &mut Rng) -> Agent,
    ) {
        for _ in 0..n {
            let p = Vec3::from_array(
                self.rng.point_in(region.min.to_array(), region.max.to_array()),
            );
            let agent = make(p, &mut self.rng);
            self.total_generated += 1;
            if self.grid.owner_of_pos(agent.position) == self.rank {
                self.kept.push(agent);
            }
        }
    }

    /// Add one agent at an explicit position (kept only on the owner).
    pub fn place(&mut self, agent: Agent) {
        self.total_generated += 1;
        if self.grid.owner_of_pos(agent.position) == self.rank {
            self.kept.push(agent);
        }
    }

    /// RNG for model-specific draws that must be identical on all ranks.
    pub fn shared_rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// Agents this rank keeps.
    pub fn into_agents(self) -> Vec<Agent> {
        self.kept
    }

    pub fn generated(&self) -> u64 {
        self.total_generated
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::agent::CellType;

    fn grid_halves() -> PartitionGrid {
        let mut g = PartitionGrid::new(Aabb::cube(20.0), 10.0);
        for i in 0..g.num_boxes() {
            let c = g.unflat(i);
            g.set_owner(i, if c[0] < 2 { 0 } else { 1 });
        }
        g
    }

    fn make(p: Vec3, _r: &mut Rng) -> Agent {
        Agent::cell(p, 1.0, CellType::A)
    }

    #[test]
    fn partition_of_agents_is_exact() {
        let g = grid_halves();
        let mut c0 = InitCtx::new(0, &g, 99);
        let mut c1 = InitCtx::new(1, &g, 99);
        c0.scatter_uniform(1000, g.whole(), make);
        c1.scatter_uniform(1000, g.whole(), make);
        let a0 = c0.into_agents();
        let a1 = c1.into_agents();
        assert_eq!(a0.len() + a1.len(), 1000, "every agent on exactly one rank");
        // Each agent is on its owner.
        assert!(a0.iter().all(|a| g.owner_of_pos(a.position) == 0));
        assert!(a1.iter().all(|a| g.owner_of_pos(a.position) == 1));
        // Roughly half on each side.
        assert!((400..600).contains(&a0.len()), "a0 = {}", a0.len());
    }

    #[test]
    fn identical_population_regardless_of_rank_count() {
        // 1 rank vs 2 ranks: the union of positions is identical.
        let mut g1 = PartitionGrid::new(Aabb::cube(20.0), 10.0);
        for i in 0..g1.num_boxes() {
            g1.set_owner(i, 0);
        }
        let g2 = grid_halves();
        let mut single = InitCtx::new(0, &g1, 7);
        single.scatter_uniform(500, g1.whole(), make);
        let mut r0 = InitCtx::new(0, &g2, 7);
        let mut r1 = InitCtx::new(1, &g2, 7);
        r0.scatter_uniform(500, g2.whole(), make);
        r1.scatter_uniform(500, g2.whole(), make);
        let mut union: Vec<[f64; 3]> = r0
            .into_agents()
            .iter()
            .chain(r1.into_agents().iter())
            .map(|a| a.position.to_array())
            .collect();
        let mut all: Vec<[f64; 3]> =
            single.into_agents().iter().map(|a| a.position.to_array()).collect();
        let key = |p: &[f64; 3]| (p[0].to_bits(), p[1].to_bits(), p[2].to_bits());
        union.sort_by_key(key);
        all.sort_by_key(key);
        assert_eq!(union, all);
    }

    #[test]
    fn place_respects_ownership() {
        let g = grid_halves();
        let mut c0 = InitCtx::new(0, &g, 1);
        c0.place(Agent::cell(Vec3::new(-15.0, 0.0, 0.0), 1.0, CellType::A)); // rank 0 side
        c0.place(Agent::cell(Vec3::new(15.0, 0.0, 0.0), 1.0, CellType::A)); // rank 1 side
        assert_eq!(c0.generated(), 2);
        assert_eq!(c0.into_agents().len(), 1);
    }
}
