//! Distributed initialization (§2.4.4): create agents directly on their
//! authoritative rank, avoiding a mass migration after setup.
//!
//! The generator stream is seeded identically on every rank; each rank
//! keeps only the agents whose position it owns. This yields *bitwise
//! identical* initial conditions regardless of rank count — the property
//! the distributed-determinism tests rely on — while still creating every
//! agent on its authoritative rank. (The paper's volume-fraction
//! optimization for very large populations trades this identity for O(n/R)
//! generation time; see `scatter_uniform_fraction`.)

use crate::core::agent::{Agent, AgentBatch, Behavior};
use crate::space::{Aabb, PartitionGrid};
use crate::util::{Rng, Vec3};

/// Initialization context handed to `Model::create_agents`.
pub struct InitCtx<'a> {
    pub rank: u32,
    pub whole: Aabb,
    grid: &'a PartitionGrid,
    rng: Rng,
    kept: AgentBatch,
    /// Scratch for the per-agent behavior set (capacity reused).
    beh_scratch: Vec<Behavior>,
    total_generated: u64,
}

impl<'a> InitCtx<'a> {
    pub fn new(rank: u32, grid: &'a PartitionGrid, seed: u64) -> Self {
        InitCtx {
            rank,
            whole: grid.whole(),
            grid,
            // Same stream on every rank — identity across rank counts.
            rng: Rng::stream(seed, 0xD157_0000),
            kept: AgentBatch::new(),
            beh_scratch: Vec::new(),
            total_generated: 0,
        }
    }

    /// Generate `n` behavior-less agents at uniform random positions in
    /// `region` via `make(position, rng)`; keep those owned by this rank.
    pub fn scatter_uniform(
        &mut self,
        n: usize,
        region: Aabb,
        mut make: impl FnMut(Vec3, &mut Rng) -> Agent,
    ) {
        self.scatter_uniform_with(n, region, |p, rng, _| make(p, rng));
    }

    /// [`InitCtx::scatter_uniform`] for agents that carry behaviors:
    /// `make(position, rng, behaviors)` fills the (pre-cleared) behavior
    /// vector alongside building the agent. `make` runs for every
    /// generated agent on every rank — before the ownership test — so
    /// the shared RNG stream stays identical across rank counts.
    pub fn scatter_uniform_with(
        &mut self,
        n: usize,
        region: Aabb,
        mut make: impl FnMut(Vec3, &mut Rng, &mut Vec<Behavior>) -> Agent,
    ) {
        for _ in 0..n {
            let p = Vec3::from_array(
                self.rng.point_in(region.min.to_array(), region.max.to_array()),
            );
            self.beh_scratch.clear();
            let agent = make(p, &mut self.rng, &mut self.beh_scratch);
            self.total_generated += 1;
            if self.grid.owner_of_pos(agent.position) == self.rank {
                self.kept.push(agent, &self.beh_scratch);
            }
        }
    }

    /// Add one behavior-less agent at an explicit position (kept only on
    /// the owner).
    pub fn place(&mut self, agent: Agent) {
        self.place_with(agent, &[]);
    }

    /// [`InitCtx::place`] with an initial behavior set.
    pub fn place_with(&mut self, agent: Agent, behaviors: &[Behavior]) {
        self.total_generated += 1;
        if self.grid.owner_of_pos(agent.position) == self.rank {
            self.kept.push(agent, behaviors);
        }
    }

    /// RNG for model-specific draws that must be identical on all ranks.
    pub fn shared_rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// The batch of agents (with behavior sets) this rank keeps.
    pub fn into_batch(self) -> AgentBatch {
        self.kept
    }

    pub fn generated(&self) -> u64 {
        self.total_generated
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::agent::CellType;

    fn grid_halves() -> PartitionGrid {
        let mut g = PartitionGrid::new(Aabb::cube(20.0), 10.0);
        for i in 0..g.num_boxes() {
            let c = g.unflat(i);
            g.set_owner(i, if c[0] < 2 { 0 } else { 1 });
        }
        g
    }

    fn make(p: Vec3, _r: &mut Rng) -> Agent {
        Agent::cell(p, 1.0, CellType::A)
    }

    #[test]
    fn partition_of_agents_is_exact() {
        let g = grid_halves();
        let mut c0 = InitCtx::new(0, &g, 99);
        let mut c1 = InitCtx::new(1, &g, 99);
        c0.scatter_uniform(1000, g.whole(), make);
        c1.scatter_uniform(1000, g.whole(), make);
        let a0 = c0.into_batch().agents;
        let a1 = c1.into_batch().agents;
        assert_eq!(a0.len() + a1.len(), 1000, "every agent on exactly one rank");
        // Each agent is on its owner.
        assert!(a0.iter().all(|a| g.owner_of_pos(a.position) == 0));
        assert!(a1.iter().all(|a| g.owner_of_pos(a.position) == 1));
        // Roughly half on each side.
        assert!((400..600).contains(&a0.len()), "a0 = {}", a0.len());
    }

    #[test]
    fn identical_population_regardless_of_rank_count() {
        // 1 rank vs 2 ranks: the union of positions is identical.
        let mut g1 = PartitionGrid::new(Aabb::cube(20.0), 10.0);
        for i in 0..g1.num_boxes() {
            g1.set_owner(i, 0);
        }
        let g2 = grid_halves();
        let mut single = InitCtx::new(0, &g1, 7);
        single.scatter_uniform(500, g1.whole(), make);
        let mut r0 = InitCtx::new(0, &g2, 7);
        let mut r1 = InitCtx::new(1, &g2, 7);
        r0.scatter_uniform(500, g2.whole(), make);
        r1.scatter_uniform(500, g2.whole(), make);
        let mut union: Vec<[f64; 3]> = r0
            .into_batch()
            .agents
            .iter()
            .chain(r1.into_batch().agents.iter())
            .map(|a| a.position.to_array())
            .collect();
        let mut all: Vec<[f64; 3]> =
            single.into_batch().agents.iter().map(|a| a.position.to_array()).collect();
        let key = |p: &[f64; 3]| (p[0].to_bits(), p[1].to_bits(), p[2].to_bits());
        union.sort_by_key(key);
        all.sort_by_key(key);
        assert_eq!(union, all);
    }

    #[test]
    fn place_respects_ownership() {
        let g = grid_halves();
        let mut c0 = InitCtx::new(0, &g, 1);
        c0.place(Agent::cell(Vec3::new(-15.0, 0.0, 0.0), 1.0, CellType::A)); // rank 0 side
        c0.place(Agent::cell(Vec3::new(15.0, 0.0, 0.0), 1.0, CellType::A)); // rank 1 side
        assert_eq!(c0.generated(), 2);
        assert_eq!(c0.into_batch().len(), 1);
    }

    #[test]
    fn scatter_with_behaviors_keeps_sets_aligned_and_streams_identically() {
        use crate::core::agent::Behavior;
        let g = grid_halves();
        let mk = |p: Vec3, rng: &mut Rng, bs: &mut Vec<Behavior>| {
            bs.push(Behavior::RandomWalk { speed: rng.uniform_range(0.5, 1.5) });
            if rng.uniform() < 0.5 {
                bs.push(Behavior::Divide);
            }
            Agent::cell(p, 1.0, CellType::A)
        };
        let mut c0 = InitCtx::new(0, &g, 123);
        let mut c1 = InitCtx::new(1, &g, 123);
        c0.scatter_uniform_with(300, g.whole(), mk);
        c1.scatter_uniform_with(300, g.whole(), mk);
        let b0 = c0.into_batch();
        let b1 = c1.into_batch();
        assert_eq!(b0.len() + b1.len(), 300);
        // Behavior sets travel with their agent: every kept agent has 1–2
        // behaviors, the first always a RandomWalk.
        for b in [&b0, &b1] {
            for i in 0..b.len() {
                let bs = b.behaviors(i);
                assert!((1..=2).contains(&bs.len()));
                assert!(matches!(bs[0], Behavior::RandomWalk { .. }));
            }
        }
    }
}
