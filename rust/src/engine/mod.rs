//! The distributed simulation engine (§2.1, Fig. 1).
//!
//! A simulation runs as `R` rank threads (simulated MPI processes). Each
//! iteration every rank executes:
//!
//! 1. **Aura update** — serialize owned agents near foreign borders, send
//!    to the owning neighbor ranks, rebuild the local aura set.
//! 2. **Mechanics** — gather K nearest neighbors per owned agent, run the
//!    AOT-compiled JAX/Pallas force kernel (or its native oracle), apply
//!    displacements and boundary conditions.
//! 3. **Model step** — model-specific behaviors (growth, division,
//!    infection, …) with spawn/removal queues.
//! 4. **Migration** — agents whose position left the owned volume move to
//!    the authoritative rank.
//! 5. **Balancing** (periodic) — RCB or diffusive repartitioning.
//! 6. **Sorting** (periodic) — Morton-order agent sorting along the NSG's
//!    own cell curve, followed by the parallel wholesale NSG rebuild
//!    ([`crate::space::NeighborSearchGrid::rebuild_owned`]).
//!
//! Intra-rank parallelism (the paper's OpenMP axis) is a scoped fork-join
//! [`pool::ThreadPool`] per rank; every parallel region — mechanics
//! gather, aura encode, NSG rebuild, model [`World::par_chunks`] — is
//! bit-deterministic regardless of thread count, which keeps the
//! MPI-hybrid modes distribution-transparent (§3.3). See
//! `ARCHITECTURE.md` for the end-to-end iteration walkthrough.

pub mod behavior;
pub mod checkpoint;
pub mod init;
pub mod launcher;
pub mod model;
pub mod pool;
pub mod sim;
pub mod world;

pub use launcher::{
    run_multiprocess, run_rank_process, run_simulation, run_simulation_with_chaos, RunResult,
};
pub use model::Model;
pub use pool::ThreadPool;
pub use world::{AuraStore, NeighborInfo, World};
