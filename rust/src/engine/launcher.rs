//! The launcher: spawn rank threads, wire the transport and the mechanics
//! service, run the simulation, aggregate results.
//!
//! This is the "seamless laptop → supercomputer" entry point (§3.4): the
//! same model code runs under any [`ParallelMode`](crate::config::ParallelMode)
//! without modification — switching modes is a config change, not a
//! recompilation (§2.5).

use super::model::Model;
use super::sim::{MechBackend, RankOutcome, RankSim};
use crate::comm::mpi::MpiWorld;
use crate::comm::FaultPlan;
use crate::config::SimConfig;
use crate::metrics::SimReport;
use crate::runtime::service::MechanicsService;
use crate::vis::insitu::Image;
use std::path::PathBuf;

/// Aggregated result of a run.
pub struct RunResult {
    pub report: SimReport,
    /// Per-iteration global stats (combined across ranks by the model).
    pub stats_history: Vec<Vec<f64>>,
    pub stat_names: Vec<&'static str>,
    pub final_agents: u64,
    /// Composited frames (present when visualization was configured).
    pub frames: Vec<Image>,
    /// Whether mechanics executed through the PJRT artifact.
    pub used_pjrt: bool,
    /// Final agent snapshot gathered from all ranks: (position, diameter,
    /// class id) — the §3.4 "positions to the master rank" step used for
    /// the convex-hull diameter and the qualitative sorting check.
    pub final_snapshot: Vec<(crate::util::Vec3, f64, u16)>,
}

/// Run a simulation: one model instance per rank from `factory(rank)`.
pub fn run_simulation<M: Model>(
    cfg: &SimConfig,
    factory: impl Fn(u32) -> M + Send + Sync,
) -> RunResult {
    run_simulation_with_chaos(cfg, factory, |_| None)
}

/// [`run_simulation`] with a per-rank fault plan: `chaos(rank)` installs
/// a deterministic fault injector on that rank's sends before the run
/// starts. This is how the rank-death suite scripts a mid-run crash
/// (`FaultPlan::with_kill_at_iteration`) inside an otherwise ordinary
/// engine run; production paths pass no plans and are untouched.
pub fn run_simulation_with_chaos<M: Model>(
    cfg: &SimConfig,
    factory: impl Fn(u32) -> M + Send + Sync,
    chaos: impl Fn(u32) -> Option<FaultPlan> + Send + Sync,
) -> RunResult {
    cfg.validate().expect("invalid SimConfig");
    let ranks = cfg.mode.ranks();
    let world = MpiWorld::new(ranks, cfg.network);
    // One PJRT service per "node" shared by all ranks (the client is not
    // Send; it lives on its own thread).
    let service = cfg
        .use_pjrt
        .then(|| MechanicsService::start(PathBuf::from(&cfg.artifacts_dir), true));
    let used_pjrt = service.as_ref().map(|s| s.using_pjrt).unwrap_or(false);

    let outcomes: Vec<RankOutcome> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..ranks as u32)
            .map(|rank| {
                let mut comm = world.communicator(rank);
                if let Some(plan) = chaos(rank) {
                    comm.install_chaos(plan);
                }
                let model = factory(rank);
                let mech = match &service {
                    Some(svc) if svc.using_pjrt => MechBackend::Service(svc.handle()),
                    _ => MechBackend::Native,
                };
                let cfg = cfg.clone();
                s.spawn(move || RankSim::new(rank, cfg, comm, model, mech).run())
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("rank thread panicked")).collect()
    });

    // Aggregate.
    let per_rank_metrics: Vec<_> = outcomes.iter().map(|o| o.metrics.clone()).collect();
    let report = SimReport::aggregate(&per_rank_metrics);
    let model = factory(u32::MAX); // combiner instance
    let iters = outcomes.iter().map(|o| o.stats_history.len()).max().unwrap_or(0);
    let mut stats_history = Vec::with_capacity(iters);
    for i in 0..iters {
        let per_rank: Vec<Vec<f64>> = outcomes
            .iter()
            .map(|o| o.stats_history.get(i).cloned().unwrap_or_default())
            .collect();
        stats_history.push(model.combine_stats(&per_rank));
    }
    let final_agents = outcomes.iter().map(|o| o.final_agents).sum();
    let mut frames = Vec::new();
    let mut final_snapshot = Vec::new();
    for o in outcomes {
        if frames.is_empty() && !o.frames.is_empty() {
            frames = o.frames;
        }
        final_snapshot.extend(o.final_snapshot);
    }
    RunResult {
        report,
        stats_history,
        stat_names: model.stat_names(),
        final_agents,
        frames,
        used_pjrt,
        final_snapshot,
    }
}
