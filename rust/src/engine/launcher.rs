//! The launcher: spawn rank threads (or real OS processes), wire the
//! transport and the mechanics service, run the simulation, aggregate
//! results.
//!
//! This is the "seamless laptop → supercomputer" entry point (§3.4): the
//! same model code runs under any [`ParallelMode`](crate::config::ParallelMode)
//! without modification — switching modes is a config change, not a
//! recompilation (§2.5). The same holds for the wire: `cfg.transport`
//! picks in-process mailboxes, Unix-domain sockets or the shared-memory
//! slab, and [`run_simulation`] threads the chosen backend through the
//! identical rank loop. [`run_multiprocess`] goes one step further and
//! spawns one *real OS process per rank* (the hidden `_rank` CLI command),
//! rendezvousing over a temporary directory and collecting per-rank
//! outcomes from binary files.

use super::model::Model;
use super::sim::{MechBackend, RankOutcome, RankSim};
use crate::comm::mpi::MpiWorld;
use crate::comm::{Communicator, FaultPlan, ShmTransport, TransportKind, UdsTransport};
use crate::config::SimConfig;
use crate::metrics::{Counter, Op, RankMetrics, SimReport};
use crate::runtime::service::MechanicsService;
use crate::util::Vec3;
use crate::vis::insitu::Image;
use std::io::{self, Read, Write};
use std::path::{Path, PathBuf};

/// Aggregated result of a run.
pub struct RunResult {
    pub report: SimReport,
    /// Per-iteration global stats (combined across ranks by the model).
    pub stats_history: Vec<Vec<f64>>,
    pub stat_names: Vec<&'static str>,
    pub final_agents: u64,
    /// Composited frames (present when visualization was configured).
    pub frames: Vec<Image>,
    /// Whether mechanics executed through the PJRT artifact.
    pub used_pjrt: bool,
    /// Final agent snapshot gathered from all ranks: (position, diameter,
    /// class id) — the §3.4 "positions to the master rank" step used for
    /// the convex-hull diameter and the qualitative sorting check.
    pub final_snapshot: Vec<(crate::util::Vec3, f64, u16)>,
    /// Per-rank send-stream audit digests (rank order; empty unless
    /// `cfg.stream_audit`). Identical seeded runs must produce identical
    /// digests on every transport backend — the determinism witness the
    /// multiprocess suite compares across in-process, UDS and shm runs.
    pub stream_crcs: Vec<u32>,
}

/// Build one rank's communicator for the configured transport. The
/// in-process backend draws from the shared `world`; the multiprocess
/// backends rendezvous over `dir` (socket + slab files) and work equally
/// from rank threads (tests) or separate OS processes (`_rank` children).
fn build_communicator(
    cfg: &SimConfig,
    world: Option<&std::sync::Arc<MpiWorld>>,
    dir: Option<&Path>,
    rank: u32,
) -> Communicator {
    let ranks = cfg.mode.ranks();
    match cfg.transport {
        TransportKind::InProcess => world.expect("in-process world").communicator(rank),
        TransportKind::Uds => {
            let dir = dir.expect("uds rendezvous dir");
            let t = UdsTransport::connect(dir, rank, ranks).expect("uds transport connect");
            Communicator::new(Box::new(t), cfg.network)
        }
        TransportKind::Shm => {
            let dir = dir.expect("shm rendezvous dir");
            let t = ShmTransport::connect(dir, rank, ranks).expect("shm transport connect");
            Communicator::new(Box::new(t), cfg.network)
        }
    }
}

/// A process-private rendezvous directory (sockets, slabs, outcome
/// files). Uniqueness comes from the pid plus a wall-clock nonce, so
/// concurrent test processes never collide.
fn fresh_rendezvous_dir(label: &str) -> io::Result<PathBuf> {
    let nonce = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos())
        .unwrap_or(0);
    let dir = std::env::temp_dir().join(format!(
        "teraagent-{label}-{}-{:x}",
        std::process::id(),
        nonce
    ));
    std::fs::create_dir_all(&dir)?;
    Ok(dir)
}

/// Run a simulation: one model instance per rank from `factory(rank)`.
pub fn run_simulation<M: Model>(
    cfg: &SimConfig,
    factory: impl Fn(u32) -> M + Send + Sync,
) -> RunResult {
    run_simulation_with_chaos(cfg, factory, |_| None)
}

/// [`run_simulation`] with a per-rank fault plan: `chaos(rank)` installs
/// a deterministic fault injector on that rank's sends before the run
/// starts. This is how the rank-death suite scripts a mid-run crash
/// (`FaultPlan::with_kill_at_iteration`) inside an otherwise ordinary
/// engine run; production paths pass no plans and are untouched.
///
/// Ranks are OS threads here under every transport: with `cfg.transport`
/// set to UDS/shm the threads talk through the real wire (socket/slab
/// files in a private rendezvous dir) — the conformance suite's
/// cheap-to-spawn configuration. For real one-process-per-rank execution
/// use [`run_multiprocess`].
pub fn run_simulation_with_chaos<M: Model>(
    cfg: &SimConfig,
    factory: impl Fn(u32) -> M + Send + Sync,
    chaos: impl Fn(u32) -> Option<FaultPlan> + Send + Sync,
) -> RunResult {
    cfg.validate().expect("invalid SimConfig");
    let ranks = cfg.mode.ranks();
    let world =
        (cfg.transport == TransportKind::InProcess).then(|| MpiWorld::new(ranks, cfg.network));
    let rendezvous = cfg
        .transport
        .multiprocess()
        .then(|| fresh_rendezvous_dir("threads").expect("rendezvous dir"));
    // One PJRT service per "node" shared by all ranks (the client is not
    // Send; it lives on its own thread).
    let service = cfg
        .use_pjrt
        .then(|| MechanicsService::start(PathBuf::from(&cfg.artifacts_dir), true));
    let used_pjrt = service.as_ref().map(|s| s.using_pjrt).unwrap_or(false);

    let outcomes: Vec<RankOutcome> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..ranks as u32)
            .map(|rank| {
                let world = world.as_ref();
                let dir = rendezvous.as_deref();
                let chaos = &chaos;
                let factory = &factory;
                let model = factory(rank);
                let mech = match &service {
                    Some(svc) if svc.using_pjrt => MechBackend::Service(svc.handle()),
                    _ => MechBackend::Native,
                };
                let cfg = cfg.clone();
                s.spawn(move || {
                    let mut comm = build_communicator(&cfg, world, dir, rank);
                    if let Some(plan) = chaos(rank) {
                        comm.install_chaos(plan);
                    }
                    RankSim::new(rank, cfg, comm, model, mech).run()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("rank thread panicked")).collect()
    });
    if let Some(dir) = rendezvous {
        std::fs::remove_dir_all(&dir).ok();
    }
    aggregate_outcomes(cfg, outcomes, &factory, used_pjrt)
}

/// Fold per-rank outcomes into the run-level result (shared by the
/// thread launcher and the multiprocess parent).
fn aggregate_outcomes<M: Model>(
    _cfg: &SimConfig,
    outcomes: Vec<RankOutcome>,
    factory: &(impl Fn(u32) -> M + Send + Sync),
    used_pjrt: bool,
) -> RunResult {
    let per_rank_metrics: Vec<_> = outcomes.iter().map(|o| o.metrics.clone()).collect();
    let report = SimReport::aggregate(&per_rank_metrics);
    let model = factory(u32::MAX); // combiner instance
    let iters = outcomes.iter().map(|o| o.stats_history.len()).max().unwrap_or(0);
    let mut stats_history = Vec::with_capacity(iters);
    for i in 0..iters {
        let per_rank: Vec<Vec<f64>> = outcomes
            .iter()
            .map(|o| o.stats_history.get(i).cloned().unwrap_or_default())
            .collect();
        stats_history.push(model.combine_stats(&per_rank));
    }
    let final_agents = outcomes.iter().map(|o| o.final_agents).sum();
    let stream_crcs = outcomes.iter().filter_map(|o| o.aura_stream_crc).collect();
    let mut frames = Vec::new();
    let mut final_snapshot = Vec::new();
    for o in outcomes {
        if frames.is_empty() && !o.frames.is_empty() {
            frames = o.frames;
        }
        final_snapshot.extend(o.final_snapshot);
    }
    RunResult {
        report,
        stats_history,
        stat_names: model.stat_names(),
        final_agents,
        frames,
        used_pjrt,
        final_snapshot,
        stream_crcs,
    }
}

// ---------------------------------------------------------------------
// Multiprocess execution: one real OS process per rank
// ---------------------------------------------------------------------

/// The `_rank` child's working loop: connect the configured multiprocess
/// transport over `rendezvous`, run the rank to completion, return its
/// outcome. Panics if `cfg.transport` is the in-process backend (a child
/// process has nobody to share mailboxes with).
pub fn run_rank_process<M: Model>(
    cfg: &SimConfig,
    rank: u32,
    rendezvous: &Path,
    model: M,
    chaos: Option<FaultPlan>,
) -> RankOutcome {
    assert!(
        cfg.transport.multiprocess(),
        "rank child needs a multiprocess transport, got {}",
        cfg.transport.name()
    );
    cfg.validate().expect("invalid SimConfig");
    let service = cfg
        .use_pjrt
        .then(|| MechanicsService::start(PathBuf::from(&cfg.artifacts_dir), true));
    let mech = match &service {
        Some(svc) if svc.using_pjrt => MechBackend::Service(svc.handle()),
        _ => MechBackend::Native,
    };
    let mut comm = build_communicator(cfg, None, Some(rendezvous), rank);
    if let Some(plan) = chaos {
        comm.install_chaos(plan);
    }
    RankSim::new(rank, cfg.clone(), comm, model, mech).run()
}

/// Spawn one real OS process per rank (the hidden `_rank` CLI command),
/// wait for all of them, read back their outcome files and aggregate
/// exactly like the thread launcher. `exe` overrides the child binary
/// (integration tests pass `env!("CARGO_BIN_EXE_teraagent")`; the CLI
/// itself re-executes `current_exe()`); `factory` is only consulted for
/// the stats combiner — each child rebuilds its model from the config's
/// benchmark name.
pub fn run_multiprocess<M: Model>(
    cfg: &SimConfig,
    factory: impl Fn(u32) -> M + Send + Sync,
    exe: Option<&Path>,
    chaos: &dyn Fn(u32) -> Option<FaultPlan>,
) -> Result<RunResult, String> {
    cfg.validate()?;
    if !cfg.transport.multiprocess() {
        return Err(format!(
            "transport {} has no multiprocess launcher (pick uds or shm)",
            cfg.transport.name()
        ));
    }
    let ranks = cfg.mode.ranks();
    let exe = match exe {
        Some(p) => p.to_path_buf(),
        None => std::env::current_exe().map_err(|e| format!("current_exe: {e}"))?,
    };
    let dir = fresh_rendezvous_dir("mp").map_err(|e| format!("rendezvous dir: {e}"))?;
    let result = run_multiprocess_in(cfg, &factory, &exe, chaos, &dir, ranks);
    std::fs::remove_dir_all(&dir).ok();
    result
}

fn run_multiprocess_in<M: Model>(
    cfg: &SimConfig,
    factory: &(impl Fn(u32) -> M + Send + Sync),
    exe: &Path,
    chaos: &dyn Fn(u32) -> Option<FaultPlan>,
    dir: &Path,
    ranks: usize,
) -> Result<RunResult, String> {
    let config_path = dir.join("config.toml");
    std::fs::write(&config_path, cfg.to_toml()).map_err(|e| format!("write config: {e}"))?;
    let mut children = Vec::with_capacity(ranks);
    for rank in 0..ranks as u32 {
        let mut cmd = std::process::Command::new(exe);
        cmd.arg("_rank")
            .arg("--rendezvous")
            .arg(dir)
            .arg("--rank")
            .arg(rank.to_string())
            .arg("--size")
            .arg(ranks.to_string())
            .arg("--config-file")
            .arg(&config_path);
        if let Some(plan) = chaos(rank) {
            for arg in chaos_plan_to_flags(&plan) {
                cmd.arg(arg);
            }
        }
        let child = cmd
            .stdout(std::process::Stdio::null())
            .spawn()
            .map_err(|e| format!("spawn rank {rank}: {e}"))?;
        children.push((rank, child));
    }
    let mut failures = Vec::new();
    for (rank, mut child) in children {
        match child.wait() {
            Ok(status) if status.success() => {}
            Ok(status) => failures.push(format!("rank {rank} exited with {status}")),
            Err(e) => failures.push(format!("rank {rank} wait: {e}")),
        }
    }
    if !failures.is_empty() {
        return Err(failures.join("; "));
    }
    let mut outcomes = Vec::with_capacity(ranks);
    for rank in 0..ranks as u32 {
        let path = dir.join(outcome_file_name(rank));
        let (file_rank, _killed, outcome) =
            read_rank_outcome(&path).map_err(|e| format!("outcome {rank}: {e}"))?;
        if file_rank != rank {
            return Err(format!("outcome file {path:?} names rank {file_rank}, want {rank}"));
        }
        outcomes.push(outcome);
    }
    // Children run their own PJRT services; the parent only reports the
    // configuration (whether the artifact was actually used is visible in
    // each child's logs, not collected here).
    Ok(aggregate_outcomes(cfg, outcomes, factory, cfg.use_pjrt))
}

/// Serialize the supported fault-plan subset into `_rank` child flags.
/// (Delay/reorder/truncate are thread-timing fault classes exercised by
/// the in-process chaos suites; the cross-process scripting surface
/// carries the categories the multiprocess chaos tests need.)
fn chaos_plan_to_flags(plan: &FaultPlan) -> Vec<String> {
    let mut args = vec!["--chaos-seed".into(), plan.seed.to_string()];
    if plan.p_drop > 0.0 {
        args.push("--chaos-drop".into());
        args.push(plan.p_drop.to_string());
    }
    if plan.p_duplicate > 0.0 {
        args.push("--chaos-dup".into());
        args.push(plan.p_duplicate.to_string());
    }
    if plan.p_bit_flip > 0.0 {
        args.push("--chaos-flip".into());
        args.push(plan.p_bit_flip.to_string());
    }
    if plan.max_faults > 0 {
        args.push("--chaos-max-faults".into());
        args.push(plan.max_faults.to_string());
    }
    if let Some(k) = plan.kill_at_iteration {
        args.push("--chaos-kill-iter".into());
        args.push(k.to_string());
    }
    // Tag scope travels only when it differs from the builder default
    // ([`FaultPlan::none`] already targets the aura stream).
    if plan.tags != FaultPlan::none(0).tags {
        args.push("--chaos-tags".into());
        let spec: Vec<String> = plan.tags.iter().map(|t| t.to_string()).collect();
        args.push(spec.join(","));
    }
    args
}

/// Name of rank `r`'s binary outcome file inside the rendezvous dir.
pub fn outcome_file_name(rank: u32) -> String {
    format!("outcome{rank}.bin")
}

const OUTCOME_MAGIC: &[u8; 4] = b"TAO1";

/// Write a rank outcome to its binary file (the `_rank` child's last
/// act). Format `TAO1`: all integers little-endian; floats as f64 bits.
/// Frames are not shipped — vis export already writes PPMs to disk.
pub fn write_rank_outcome(
    path: &Path,
    rank: u32,
    killed: bool,
    o: &RankOutcome,
) -> io::Result<()> {
    let mut buf = Vec::with_capacity(256 + o.final_snapshot.len() * 34);
    buf.extend_from_slice(OUTCOME_MAGIC);
    buf.extend_from_slice(&rank.to_le_bytes());
    buf.push(killed as u8);
    buf.extend_from_slice(&o.final_agents.to_le_bytes());
    match o.aura_stream_crc {
        Some(crc) => {
            buf.push(1);
            buf.extend_from_slice(&crc.to_le_bytes());
        }
        None => {
            buf.push(0);
            buf.extend_from_slice(&0u32.to_le_bytes());
        }
    }
    buf.extend_from_slice(&o.wire_bytes_sent.to_le_bytes());
    buf.extend_from_slice(&(o.final_snapshot.len() as u64).to_le_bytes());
    for (pos, diam, kind) in &o.final_snapshot {
        for v in [pos.x, pos.y, pos.z, *diam] {
            buf.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        buf.extend_from_slice(&kind.to_le_bytes());
    }
    buf.extend_from_slice(&(o.stats_history.len() as u64).to_le_bytes());
    for row in &o.stats_history {
        buf.extend_from_slice(&(row.len() as u64).to_le_bytes());
        for v in row {
            buf.extend_from_slice(&v.to_bits().to_le_bytes());
        }
    }
    write_metrics(&mut buf, &o.metrics);
    // Write-then-rename so the parent never reads a torn file.
    let tmp = path.with_extension("tmp");
    let mut f = std::fs::File::create(&tmp)?;
    f.write_all(&buf)?;
    f.sync_all()?;
    drop(f);
    std::fs::rename(&tmp, path)
}

fn write_metrics(buf: &mut Vec<u8>, m: &RankMetrics) {
    buf.extend_from_slice(&(m.iteration_secs.len() as u64).to_le_bytes());
    for v in &m.iteration_secs {
        buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    buf.extend_from_slice(&(m.iteration_cpu_secs.len() as u64).to_le_bytes());
    for v in &m.iteration_cpu_secs {
        buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    buf.extend_from_slice(&m.network_secs.to_bits().to_le_bytes());
    buf.extend_from_slice(&m.peak_mem_bytes.to_le_bytes());
    buf.extend_from_slice(&(Op::ALL.len() as u64).to_le_bytes());
    for op in Op::ALL {
        buf.extend_from_slice(&m.op_secs(op).to_bits().to_le_bytes());
    }
    buf.extend_from_slice(&(Counter::ALL.len() as u64).to_le_bytes());
    for c in Counter::ALL {
        buf.extend_from_slice(&m.counter(c).to_le_bytes());
    }
}

/// Read a `TAO1` outcome file back: `(rank, killed, outcome)`.
pub fn read_rank_outcome(path: &Path) -> io::Result<(u32, bool, RankOutcome)> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut bytes)?;
    let mut r = Cursor { bytes: &bytes, off: 0 };
    let magic = r.take(4)?;
    if magic != OUTCOME_MAGIC {
        return Err(bad_data("bad outcome magic"));
    }
    let rank = r.u32()?;
    let killed = r.u8()? != 0;
    let final_agents = r.u64()?;
    let has_crc = r.u8()? != 0;
    let crc = r.u32()?;
    let wire_bytes_sent = r.u64()?;
    let n_snap = r.u64()? as usize;
    if n_snap > bytes.len() {
        return Err(bad_data("snapshot length exceeds file"));
    }
    let mut final_snapshot = Vec::with_capacity(n_snap);
    for _ in 0..n_snap {
        let x = r.f64()?;
        let y = r.f64()?;
        let z = r.f64()?;
        let diam = r.f64()?;
        let kind = r.u16()?;
        final_snapshot.push((Vec3 { x, y, z }, diam, kind));
    }
    let n_rows = r.u64()? as usize;
    if n_rows > bytes.len() {
        return Err(bad_data("stats row count exceeds file"));
    }
    let mut stats_history = Vec::with_capacity(n_rows);
    for _ in 0..n_rows {
        let n_cols = r.u64()? as usize;
        if n_cols > bytes.len() {
            return Err(bad_data("stats column count exceeds file"));
        }
        let mut row = Vec::with_capacity(n_cols);
        for _ in 0..n_cols {
            row.push(r.f64()?);
        }
        stats_history.push(row);
    }
    let metrics = read_metrics(&mut r)?;
    let outcome = RankOutcome {
        metrics,
        stats_history,
        final_agents,
        frames: Vec::new(),
        final_snapshot,
        aura_stream_crc: has_crc.then_some(crc),
        wire_bytes_sent,
    };
    Ok((rank, killed, outcome))
}

fn read_metrics(r: &mut Cursor<'_>) -> io::Result<RankMetrics> {
    let mut m = RankMetrics::new();
    let n = r.u64()? as usize;
    if n > r.bytes.len() {
        return Err(bad_data("iteration count exceeds file"));
    }
    for _ in 0..n {
        m.iteration_secs.push(r.f64()?);
    }
    let n = r.u64()? as usize;
    if n > r.bytes.len() {
        return Err(bad_data("cpu iteration count exceeds file"));
    }
    for _ in 0..n {
        m.iteration_cpu_secs.push(r.f64()?);
    }
    m.network_secs = r.f64()?;
    m.peak_mem_bytes = r.u64()?;
    let n_ops = r.u64()? as usize;
    if n_ops != Op::ALL.len() {
        return Err(bad_data("op table size mismatch"));
    }
    for op in Op::ALL {
        let secs = r.f64()?;
        if secs > 0.0 {
            m.add_op(op, secs);
        }
    }
    let n_ctrs = r.u64()? as usize;
    if n_ctrs != Counter::ALL.len() {
        return Err(bad_data("counter table size mismatch"));
    }
    for c in Counter::ALL {
        let v = r.u64()?;
        if v > 0 {
            m.count(c, v);
        }
    }
    Ok(m)
}

fn bad_data(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.to_string())
}

/// Bounds-checked little-endian reader over the outcome bytes.
struct Cursor<'a> {
    bytes: &'a [u8],
    off: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> io::Result<&'a [u8]> {
        let end = self.off.checked_add(n).filter(|&e| e <= self.bytes.len());
        let end = end.ok_or_else(|| bad_data("truncated outcome file"))?;
        let out = &self.bytes[self.off..end];
        self.off = end;
        Ok(out)
    }

    fn u8(&mut self) -> io::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> io::Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> io::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> io::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> io::Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outcome_file_round_trips() {
        let mut metrics = RankMetrics::new();
        metrics.add_op(Op::AuraUpdate, 1.25);
        metrics.count(Counter::MessagesSent, 42);
        metrics.iteration_secs = vec![0.5, 0.25];
        metrics.iteration_cpu_secs = vec![0.4, 0.2];
        metrics.network_secs = 0.125;
        metrics.peak_mem_bytes = 1 << 20;
        let o = RankOutcome {
            metrics,
            stats_history: vec![vec![1.0, 2.0], vec![3.0]],
            final_agents: 7,
            frames: Vec::new(),
            final_snapshot: vec![(Vec3 { x: 1.0, y: -2.0, z: 3.5 }, 10.0, 3)],
            aura_stream_crc: Some(0xDEAD_BEEF),
            wire_bytes_sent: 9001,
        };
        let dir = fresh_rendezvous_dir("outcometest").unwrap();
        let path = dir.join(outcome_file_name(2));
        write_rank_outcome(&path, 2, true, &o).unwrap();
        let (rank, killed, back) = read_rank_outcome(&path).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        assert_eq!(rank, 2);
        assert!(killed);
        assert_eq!(back.final_agents, 7);
        assert_eq!(back.aura_stream_crc, Some(0xDEAD_BEEF));
        assert_eq!(back.wire_bytes_sent, 9001);
        assert_eq!(back.final_snapshot, o.final_snapshot);
        assert_eq!(back.stats_history, o.stats_history);
        assert_eq!(back.metrics.op_secs(Op::AuraUpdate), 1.25);
        assert_eq!(back.metrics.counter(Counter::MessagesSent), 42);
        assert_eq!(back.metrics.iteration_secs, vec![0.5, 0.25]);
        assert_eq!(back.metrics.network_secs, 0.125);
        assert_eq!(back.metrics.peak_mem_bytes, 1 << 20);
    }

    #[test]
    fn outcome_reader_rejects_garbage() {
        let dir = fresh_rendezvous_dir("outcomebad").unwrap();
        let path = dir.join("junk.bin");
        std::fs::write(&path, b"NOPE").unwrap();
        assert!(read_rank_outcome(&path).is_err());
        std::fs::write(&path, b"TAO1\x01").unwrap();
        assert!(read_rank_outcome(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn chaos_flags_cover_scripted_plan() {
        let plan = crate::comm::FaultPlan::none(9)
            .with_drop(0.25)
            .with_duplicate(0.5)
            .with_bit_flip(0.125)
            .with_max_faults(3)
            .with_kill_at_iteration(7);
        let flags = chaos_plan_to_flags(&plan);
        let joined = flags.join(" ");
        assert!(joined.contains("--chaos-seed 9"));
        assert!(joined.contains("--chaos-drop 0.25"));
        assert!(joined.contains("--chaos-dup 0.5"));
        assert!(joined.contains("--chaos-flip 0.125"));
        assert!(joined.contains("--chaos-max-faults 3"));
        assert!(joined.contains("--chaos-kill-iter 7"));
        // Default tag scope (aura) travels implicitly; a widened scope
        // must be spelled out.
        assert!(!joined.contains("--chaos-tags"));
        let widened = crate::comm::FaultPlan::none(9).with_tags(vec![
            crate::comm::mpi::tags::AURA,
            crate::comm::mpi::tags::MIGRATION,
        ]);
        let joined = chaos_plan_to_flags(&widened).join(" ");
        assert!(joined.contains("--chaos-tags"));
        assert!(joined.contains(&format!(
            "{},{}",
            crate::comm::mpi::tags::AURA,
            crate::comm::mpi::tags::MIGRATION
        )));
    }
}
