//! Checkpoint / restore (the paper's backup path, §2.5).
//!
//! BioDynaMo/TeraAgent can back up whole simulations to disk and resume
//! them; in the distributed engine this is also where local→global
//! identifier translation happens ("if the agent ... is written to disk
//! as part of a backup or checkpoint"). A checkpoint is one TA IO message
//! per rank plus a small header (iteration, rank, agent count, payload
//! CRC) — the same serialization path as the wire, so the format is
//! exercised end-to-end.
//!
//! Checkpoints are the last rung of the recovery ladder
//! (retry → resync → restore), so they are written to survive the very
//! failures they guard against: each file lands via `.tmp` + atomic
//! rename (a crash mid-write leaves the previous checkpoint intact, never
//! a half-written current one), and the header carries a CRC32 over
//! header fields + payload so a torn or bit-rotted file is rejected on
//! read. [`restore_latest_valid`] walks a rank's checkpoints newest-first
//! and returns the first one that passes validation.
//!
//! # Manifests: cross-rank agreement
//!
//! A per-rank newest-valid scan is not enough for a *distributed*
//! restore: if rank 0's newest checkpoint is torn but rank 1's is fine,
//! picking per-rank independently silently restores divergent iterations.
//! Each completed checkpoint round therefore also writes a [`Manifest`]
//! (`manifest_iter_<iteration>.tamf`) recording the rank count and every
//! rank's agent count + checkpoint CRC. [`latest_agreed_iteration`]
//! walks manifests newest-first and returns the first iteration at which
//! **every** listed rank's file is present and CRC-valid — the agreement
//! point survivors roll back to together, including after a rank death,
//! when [`restore_resharded_mapped`] repartitions the merged population
//! over the surviving rank ids (any set, not just a prefix — manifest
//! entries carry explicit rank ids since v2).

use crate::core::agent::AgentBatch;
use crate::core::resource_manager::ResourceManager;
use crate::io::buffer::AlignedBuf;
use crate::io::ta_io;
use crate::space::partition::{PartitionGrid, RankId};
use crate::util::crc32::Crc32;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

const MAGIC: u32 = 0x5441_4350; // "TACP"
/// v2: 32-byte header ending in a CRC32 over bytes 0..28 + payload.
const VERSION: u32 = 2;
const HEADER_BYTES: usize = 32;

const MANIFEST_MAGIC: u32 = 0x5441_4D46; // "TAMF"
/// v2: per-rank records carry an explicit rank id, so a manifest can
/// describe *any* survivor set — not just the dense prefix 0..n. v1
/// (dense, rank implied by index) is still read.
const MANIFEST_VERSION: u32 = 2;
/// `[magic u32][version u32][rank_count u32][reserved u32][iteration u64]`.
const MANIFEST_HEAD_BYTES: usize = 24;
/// v1 per-rank record: `[agents u64][crc u32]` (rank implied by index).
const MANIFEST_ENTRY_BYTES_V1: usize = 12;
/// v2 per-rank record: `[rank u32][agents u64][crc u32]`.
const MANIFEST_ENTRY_BYTES: usize = 16;
/// Upper bound on a plausible rank count — anything larger in a manifest
/// header is corruption, rejected before it can size an allocation.
const MANIFEST_MAX_RANKS: u32 = 1 << 20;

/// Canonical checkpoint file name for `(rank, iteration)`.
pub fn checkpoint_name(rank: u32, iteration: u64) -> String {
    format!("rank_{rank:04}_iter_{iteration:08}.tacp")
}

/// Canonical manifest file name for `iteration`.
pub fn manifest_name(iteration: u64) -> String {
    format!("manifest_iter_{iteration:08}.tamf")
}

/// Checkpoint metadata.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CheckpointInfo {
    pub rank: u32,
    pub iteration: u64,
    pub agents: u64,
}

/// Write one rank's agents to `<dir>/rank_<rank>_iter_<iteration>.tacp`.
/// Global-id translation happens here: every agent gets a global id if it
/// does not have one yet (§2.5).
///
/// The bytes are staged in a `.tmp` sibling and atomically renamed into
/// place, so a crash mid-write can only ever lose the checkpoint being
/// written — never corrupt an existing one under the final name.
pub fn write_checkpoint(
    dir: impl AsRef<Path>,
    rank: u32,
    iteration: u64,
    rm: &mut ResourceManager,
) -> std::io::Result<PathBuf> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir)?;
    let ids = rm.ids();
    for id in &ids {
        rm.ensure_global_id(*id);
    }
    // Columnar encode straight out of the SoA store — behavior tails
    // stream from the flat arena, so checkpoints carry the whole agent.
    let cols = rm.columns();
    let mut payload = AlignedBuf::new();
    ta_io::serialize_columns_into(&cols, &ids, &mut payload);
    let mut head = [0u8; HEADER_BYTES];
    head[0..4].copy_from_slice(&MAGIC.to_le_bytes());
    head[4..8].copy_from_slice(&VERSION.to_le_bytes());
    head[8..12].copy_from_slice(&rank.to_le_bytes());
    head[12..20].copy_from_slice(&iteration.to_le_bytes());
    head[20..28].copy_from_slice(&(ids.len() as u64).to_le_bytes());
    let crc = Crc32::new().update(&head[..28]).update(payload.as_slice()).finalize();
    head[28..32].copy_from_slice(&crc.to_le_bytes());
    let path = dir.join(checkpoint_name(rank, iteration));
    let tmp = dir.join(format!("{}.tmp", checkpoint_name(rank, iteration)));
    {
        let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
        f.write_all(&head)?;
        f.write_all(payload.as_slice())?;
        f.flush()?;
        f.into_inner().map_err(|e| e.into_error())?.sync_all()?;
    }
    std::fs::rename(&tmp, &path)?;
    Ok(path)
}

/// Read a checkpoint file back into (info, batch) — agent headers plus
/// their behavior sets. Rejects anything that fails validation — wrong
/// magic/version, CRC mismatch (torn write, bit rot), unparsable payload,
/// or an agent count disagreeing with the header — with `InvalidData`,
/// so callers can fall back to an older checkpoint
/// ([`restore_latest_valid`]).
pub fn read_checkpoint(path: impl AsRef<Path>) -> std::io::Result<(CheckpointInfo, AgentBatch)> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut head = [0u8; HEADER_BYTES];
    f.read_exact(&mut head)?;
    let magic = u32::from_le_bytes(head[0..4].try_into().expect("fixed slice"));
    let version = u32::from_le_bytes(head[4..8].try_into().expect("fixed slice"));
    if magic != MAGIC || version != VERSION {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("bad checkpoint header: magic={magic:#x} version={version}"),
        ));
    }
    let info = CheckpointInfo {
        rank: u32::from_le_bytes(head[8..12].try_into().expect("fixed slice")),
        iteration: u64::from_le_bytes(head[12..20].try_into().expect("fixed slice")),
        agents: u64::from_le_bytes(head[20..28].try_into().expect("fixed slice")),
    };
    let stored_crc = u32::from_le_bytes(head[28..32].try_into().expect("fixed slice"));
    let mut payload = Vec::new();
    f.read_to_end(&mut payload)?;
    let actual_crc = Crc32::new().update(&head[..28]).update(&payload).finalize();
    if actual_crc != stored_crc {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("checkpoint CRC mismatch: stored {stored_crc:#10x} actual {actual_crc:#10x}"),
        ));
    }
    let view = ta_io::TaView::parse(AlignedBuf::from_bytes(&payload))
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))?;
    let mut batch = AgentBatch::new();
    view.materialize_batch_into(&mut batch);
    if batch.len() as u64 != info.agents {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("agent count mismatch: header {} payload {}", info.agents, batch.len()),
        ));
    }
    Ok((info, batch))
}

/// Validate a checkpoint file's framing (magic, version, CRC over header
/// + payload) without parsing the payload into agents. Returns the
/// header info plus the file's CRC — what manifest writing and manifest
/// verification need, at a fraction of [`read_checkpoint`]'s cost.
pub fn verify_checkpoint(path: impl AsRef<Path>) -> std::io::Result<(CheckpointInfo, u32)> {
    let bytes = std::fs::read(path)?;
    let Some(head) = bytes.get(..HEADER_BYTES) else {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("checkpoint shorter than its header: {} bytes", bytes.len()),
        ));
    };
    let magic = u32::from_le_bytes(head[0..4].try_into().expect("fixed slice"));
    let version = u32::from_le_bytes(head[4..8].try_into().expect("fixed slice"));
    if magic != MAGIC || version != VERSION {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("bad checkpoint header: magic={magic:#x} version={version}"),
        ));
    }
    let info = CheckpointInfo {
        rank: u32::from_le_bytes(head[8..12].try_into().expect("fixed slice")),
        iteration: u64::from_le_bytes(head[12..20].try_into().expect("fixed slice")),
        agents: u64::from_le_bytes(head[20..28].try_into().expect("fixed slice")),
    };
    let stored_crc = u32::from_le_bytes(head[28..32].try_into().expect("fixed slice"));
    let actual_crc =
        Crc32::new().update(&bytes[..28]).update(&bytes[HEADER_BYTES..]).finalize();
    if actual_crc != stored_crc {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("checkpoint CRC mismatch: stored {stored_crc:#10x} actual {actual_crc:#10x}"),
        ));
    }
    Ok((info, stored_crc))
}

/// Restore a batch into a fresh ResourceManager (fresh local ids; global
/// ids preserved — the constant identifier of §2.5). Behavior sets land
/// in the manager's flat arena.
pub fn restore_into(rm: &mut ResourceManager, batch: AgentBatch) {
    for (a, bs) in batch.iter() {
        rm.add_with_behaviors(*a, bs);
    }
}

/// One rank's record in a [`Manifest`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ManifestEntry {
    /// The rank id that wrote the checkpoint. Explicit (not an index)
    /// so a manifest written after a mid-rank death can describe the
    /// surviving set, e.g. `{0, 2, 3}`.
    pub rank: u32,
    /// Agent count that rank checkpointed.
    pub agents: u64,
    /// The CRC32 stored in that rank's checkpoint header — binds the
    /// manifest to the exact bytes on disk, so a later rewrite or
    /// corruption of the file invalidates the agreement.
    pub crc: u32,
}

/// Cross-rank checkpoint agreement record: "at `iteration`, the listed
/// ranks wrote these checkpoints". Written once per completed
/// checkpoint round, it is what lets survivors of a rank death agree on
/// a rollback point without any collective — the manifest is on shared
/// storage and self-validating. Since v2 the listed ranks need not form
/// the prefix `0..rank_count`: entries carry explicit, strictly
/// ascending rank ids.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Manifest {
    pub iteration: u64,
    pub rank_count: u32,
    /// One entry per listed rank, ascending by rank id.
    pub ranks: Vec<ManifestEntry>,
}

impl Manifest {
    /// The rank ids this manifest covers, ascending.
    pub fn rank_ids(&self) -> Vec<u32> {
        self.ranks.iter().map(|e| e.rank).collect()
    }
}

/// Write `m` to `<dir>/manifest_iter_<iteration>.tamf` (`.tmp` + atomic
/// rename, like checkpoints). Layout: 24-byte header
/// `[magic][version][rank_count][reserved][iteration u64]`, then
/// `rank_count × [rank u32][agents u64][crc u32]`, then a trailing
/// CRC32 over all preceding bytes.
pub fn write_manifest(dir: impl AsRef<Path>, m: &Manifest) -> std::io::Result<PathBuf> {
    assert_eq!(m.ranks.len(), m.rank_count as usize, "one entry per listed rank");
    assert!(
        m.ranks.windows(2).all(|w| w[0].rank < w[1].rank),
        "manifest entries must ascend by rank id"
    );
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir)?;
    let mut bytes =
        Vec::with_capacity(MANIFEST_HEAD_BYTES + m.ranks.len() * MANIFEST_ENTRY_BYTES + 4);
    bytes.extend_from_slice(&MANIFEST_MAGIC.to_le_bytes());
    bytes.extend_from_slice(&MANIFEST_VERSION.to_le_bytes());
    bytes.extend_from_slice(&m.rank_count.to_le_bytes());
    bytes.extend_from_slice(&0u32.to_le_bytes());
    bytes.extend_from_slice(&m.iteration.to_le_bytes());
    for e in &m.ranks {
        bytes.extend_from_slice(&e.rank.to_le_bytes());
        bytes.extend_from_slice(&e.agents.to_le_bytes());
        bytes.extend_from_slice(&e.crc.to_le_bytes());
    }
    let crc = Crc32::new().update(&bytes).finalize();
    bytes.extend_from_slice(&crc.to_le_bytes());
    let path = dir.join(manifest_name(m.iteration));
    let tmp = dir.join(format!("{}.tmp", manifest_name(m.iteration)));
    {
        let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
        f.write_all(&bytes)?;
        f.flush()?;
        f.into_inner().map_err(|e| e.into_error())?.sync_all()?;
    }
    std::fs::rename(&tmp, &path)?;
    Ok(path)
}

/// Read and validate a manifest file. Every failure — truncation, wrong
/// magic/version, an implausible rank count, a length that disagrees
/// with the rank count, or a trailing-CRC mismatch — is a typed
/// `InvalidData` error, never a panic: manifests sit on the same storage
/// as checkpoints and get the same adversarial treatment.
pub fn read_manifest(path: impl AsRef<Path>) -> std::io::Result<Manifest> {
    let bad = |msg: String| std::io::Error::new(std::io::ErrorKind::InvalidData, msg);
    let bytes = std::fs::read(path)?;
    let Some(head) = bytes.get(..MANIFEST_HEAD_BYTES) else {
        return Err(bad(format!("manifest shorter than its header: {} bytes", bytes.len())));
    };
    let magic = u32::from_le_bytes(head[0..4].try_into().expect("fixed slice"));
    let version = u32::from_le_bytes(head[4..8].try_into().expect("fixed slice"));
    if magic != MANIFEST_MAGIC || !(1..=MANIFEST_VERSION).contains(&version) {
        return Err(bad(format!("bad manifest header: magic={magic:#x} version={version}")));
    }
    let rank_count = u32::from_le_bytes(head[8..12].try_into().expect("fixed slice"));
    if rank_count == 0 || rank_count > MANIFEST_MAX_RANKS {
        return Err(bad(format!("implausible manifest rank count {rank_count}")));
    }
    let iteration = u64::from_le_bytes(head[16..24].try_into().expect("fixed slice"));
    let entry_bytes =
        if version == 1 { MANIFEST_ENTRY_BYTES_V1 } else { MANIFEST_ENTRY_BYTES };
    let want_len = MANIFEST_HEAD_BYTES + rank_count as usize * entry_bytes + 4;
    if bytes.len() != want_len {
        return Err(bad(format!(
            "manifest length {} disagrees with rank count {rank_count} (want {want_len})",
            bytes.len()
        )));
    }
    let body_len = want_len - 4;
    let stored_crc =
        u32::from_le_bytes(bytes[body_len..].try_into().expect("fixed 4-byte tail"));
    let actual_crc = Crc32::new().update(&bytes[..body_len]).finalize();
    if actual_crc != stored_crc {
        return Err(bad(format!(
            "manifest CRC mismatch: stored {stored_crc:#10x} actual {actual_crc:#10x}"
        )));
    }
    let mut ranks = Vec::with_capacity(rank_count as usize);
    for r in 0..rank_count as usize {
        let off = MANIFEST_HEAD_BYTES + r * entry_bytes;
        // v1 manifests are dense: the rank id is the entry index.
        let (rank, off) = if version == 1 {
            (r as u32, off)
        } else {
            (u32::from_le_bytes(bytes[off..off + 4].try_into().expect("fixed slice")), off + 4)
        };
        ranks.push(ManifestEntry {
            rank,
            agents: u64::from_le_bytes(bytes[off..off + 8].try_into().expect("fixed slice")),
            crc: u32::from_le_bytes(bytes[off + 8..off + 12].try_into().expect("fixed slice")),
        });
    }
    if ranks.windows(2).any(|w| w[0].rank >= w[1].rank) {
        return Err(bad("manifest rank ids not strictly ascending".to_string()));
    }
    Ok(Manifest { iteration, rank_count, ranks })
}

/// The agreement scan: walk manifests in `dir` newest-first and return
/// the first whose referenced checkpoints are **all** present, CRC-valid,
/// and consistent with the manifest (rank, iteration, agent count, CRC).
/// A manifest whose own bytes fail validation, or that references a
/// missing/torn/stale checkpoint, is skipped — survivors keep walking
/// back until every rank's state exists at one iteration. `Ok(None)`
/// when no agreed iteration exists.
pub fn latest_agreed_iteration(dir: impl AsRef<Path>) -> std::io::Result<Option<Manifest>> {
    let dir = dir.as_ref();
    let mut manifests: Vec<PathBuf> = std::fs::read_dir(dir)?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("manifest_iter_") && n.ends_with(".tamf"))
        })
        .collect();
    // Zero-padded iterations: lexicographic order is iteration order.
    manifests.sort();
    'next_manifest: for path in manifests.iter().rev() {
        let Ok(m) = read_manifest(path) else { continue };
        for want in &m.ranks {
            let ckpt = dir.join(checkpoint_name(want.rank, m.iteration));
            let Ok((info, crc)) = verify_checkpoint(&ckpt) else { continue 'next_manifest };
            let matches = info.rank == want.rank
                && info.iteration == m.iteration
                && info.agents == want.agents
                && crc == want.crc;
            if !matches {
                continue 'next_manifest;
            }
        }
        return Ok(Some(m));
    }
    Ok(None)
}

/// What an elastic restore hands back to one survivor.
#[derive(Debug)]
pub struct ReshardOutcome {
    /// The agents (with behavior sets) this rank owns under the new
    /// partition, in a deterministic order (old-rank-major checkpoint
    /// order) — identical on every survivor that filters for the same
    /// rank.
    pub agents: AgentBatch,
    /// Total agents across all old ranks' checkpoints (accounting).
    pub total_agents: u64,
}

/// Elastic restore: read **all** `old_ranks` checkpoint files at
/// `iteration`, re-run RCB over the merged population for the surviving
/// rank count, install the new ownership into `grid`, and return the
/// agents `my_rank` owns under it.
///
/// Determinism across the rank-count change: the per-box weights are a
/// pure function of the checkpointed agent positions, and
/// [`rcb_partition`](crate::balance::rcb::rcb_partition) is
/// deterministic, so every survivor — each running this independently,
/// with no collective — computes the *same* ownership map and a
/// partition of the *same* merged agent sequence. `new_ranks` is the
/// surviving rank count; callers pass a grid sized for the world (its
/// previous owners are irrelevant — ownership is recomputed from
/// scratch, which is also what adopts the dead rank's orphaned boxes).
pub fn restore_resharded(
    dir: impl AsRef<Path>,
    iteration: u64,
    old_ranks: u32,
    new_ranks: u32,
    grid: &mut PartitionGrid,
    my_rank: u32,
) -> std::io::Result<ReshardOutcome> {
    assert!(new_ranks >= 1 && my_rank < new_ranks);
    let old: Vec<u32> = (0..old_ranks).collect();
    let new: Vec<u32> = (0..new_ranks).collect();
    restore_resharded_mapped(dir, iteration, &old, &new, grid, my_rank)
}

/// The general elastic restore: `old_rank_ids` names the checkpoint
/// files to merge (usually a manifest's [`Manifest::rank_ids`]) and
/// `survivors` the — not necessarily contiguous — rank ids to
/// repartition onto. RCB runs over `survivors.len()` parts; part `i`
/// maps to rank id `survivors[i]`, so a mid-rank death (`{0, 2, 3}`
/// surviving from 4) reshards exactly like a tail death. Every survivor
/// runs this independently on the same inputs and computes the same
/// ownership map.
pub fn restore_resharded_mapped(
    dir: impl AsRef<Path>,
    iteration: u64,
    old_rank_ids: &[u32],
    survivors: &[u32],
    grid: &mut PartitionGrid,
    my_rank: u32,
) -> std::io::Result<ReshardOutcome> {
    assert!(!survivors.is_empty() && survivors.contains(&my_rank));
    let dir = dir.as_ref();
    let mut all = AgentBatch::new();
    for &r in old_rank_ids {
        let (_info, mut batch) = read_checkpoint(dir.join(checkpoint_name(r, iteration)))?;
        all.append(&mut batch);
    }
    let total_agents = all.len() as u64;
    let mut weights = vec![0f64; grid.num_boxes()];
    for a in &all.agents {
        weights[grid.box_of(a.position)] += 1.0;
    }
    grid.clear_weights();
    for (i, w) in weights.iter().enumerate() {
        if *w > 0.0 {
            grid.set_weight(i, *w);
        }
    }
    let parts = crate::balance::rcb::rcb_partition(grid, survivors.len() as u32);
    let owners: Vec<RankId> = parts.into_iter().map(|i| survivors[i as usize]).collect();
    grid.set_owners(owners);
    all.retain(|a| grid.owner_of_pos(a.position) == my_rank);
    Ok(ReshardOutcome { agents: all, total_agents })
}

/// List checkpoint files for an iteration, ordered by rank.
pub fn find_checkpoints(dir: impl AsRef<Path>, iteration: u64) -> std::io::Result<Vec<PathBuf>> {
    let suffix = format!("_iter_{iteration:08}.tacp");
    let mut out: Vec<PathBuf> = std::fs::read_dir(dir)?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.file_name().and_then(|n| n.to_str()).is_some_and(|n| n.ends_with(&suffix)))
        .collect();
    out.sort();
    Ok(out)
}

/// Last-resort recovery: scan `dir` for this rank's checkpoints, newest
/// iteration first, and return the first one that passes full validation
/// (magic, version, CRC, payload parse, agent count). Invalid or torn
/// files are skipped, not fatal — that is the point of keeping more than
/// one. Returns `Ok(None)` when no valid checkpoint exists.
///
/// When manifests exist in `dir`, only a manifest-**agreed** iteration is
/// eligible — the newest at which *every* rank's checkpoint validates
/// ([`latest_agreed_iteration`]). This is the divergent-restore fix: if
/// rank 0's newest file is torn, every rank rolls back together to the
/// newest iteration all ranks still hold, instead of each rank silently
/// picking its own newest-valid. The per-rank scan remains as the
/// fallback for directories with no manifests (single-rank runs, old
/// layouts).
pub fn restore_latest_valid(
    dir: impl AsRef<Path>,
    rank: u32,
) -> std::io::Result<Option<(CheckpointInfo, AgentBatch)>> {
    if let Some(m) = latest_agreed_iteration(&dir)? {
        let path = dir.as_ref().join(checkpoint_name(rank, m.iteration));
        return match read_checkpoint(&path) {
            Ok(ok) => Ok(Some(ok)),
            // This rank has no file at the agreed iteration (e.g. it
            // joined after the manifest was written): nothing to restore.
            Err(_) => Ok(None),
        };
    }
    let prefix = format!("rank_{rank:04}_iter_");
    let mut candidates: Vec<PathBuf> = std::fs::read_dir(dir)?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with(&prefix) && n.ends_with(".tacp"))
        })
        .collect();
    // Names embed a zero-padded iteration, so lexicographic order is
    // iteration order; walk newest → oldest.
    candidates.sort();
    for path in candidates.iter().rev() {
        if let Ok((info, agents)) = read_checkpoint(path) {
            return Ok(Some((info, agents)));
        }
    }
    Ok(None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::agent::{Agent, CellType, SirState};
    use crate::util::Vec3;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("teraagent_ckpt_{name}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn populate(rm: &mut ResourceManager, n: usize) {
        use crate::core::agent::{person_behaviors, tumor_cell_behaviors};
        for i in 0..n {
            let pos = Vec3::new(i as f64, 2.0 * i as f64, -(i as f64));
            // Heterogeneous behavior sets (0, 2, 1 entries) so checkpoints
            // exercise the behavior-tail round-trip, not just headers.
            match i % 3 {
                0 => rm.add(Agent::cell(pos, 5.0, CellType::B)),
                1 => rm.add_with_behaviors(
                    Agent::person(pos, SirState::Infected),
                    &person_behaviors(),
                ),
                _ => rm.add_with_behaviors(
                    Agent::tumor_cell(pos, 3.0),
                    &tumor_cell_behaviors(3.0),
                ),
            };
        }
    }

    #[test]
    fn round_trip_preserves_agents_and_assigns_global_ids() {
        let dir = tmpdir("rt");
        let mut rm = ResourceManager::new(3);
        populate(&mut rm, 50);
        let path = write_checkpoint(&dir, 3, 17, &mut rm).unwrap();
        // Translation happened: every agent now has a global id.
        assert!(rm.iter().all(|a| a.global_id.is_set()));
        let (info, batch) = read_checkpoint(&path).unwrap();
        assert_eq!(info, CheckpointInfo { rank: 3, iteration: 17, agents: 50 });
        assert_eq!(batch.len(), 50);
        // Same multiset of (global id, position, kind).
        let key = |a: &Agent| (a.global_id, a.position.x.to_bits(), a.kind.class_id());
        let mut want: Vec<_> = rm.iter().map(key).collect();
        let mut got: Vec<_> = batch.agents.iter().map(key).collect();
        want.sort();
        got.sort();
        assert_eq!(want, got);
        // Behavior sets ride along: match each restored entry to its
        // source by global id and compare the slices.
        for (a, bs) in batch.iter() {
            let src = rm.iter().find(|s| s.global_id == a.global_id).unwrap();
            assert_eq!(rm.behaviors(src.local_id).unwrap(), bs);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn restore_into_fresh_manager() {
        let dir = tmpdir("restore");
        let mut rm = ResourceManager::new(0);
        populate(&mut rm, 20);
        let path = write_checkpoint(&dir, 0, 5, &mut rm).unwrap();
        let (_, batch) = read_checkpoint(&path).unwrap();
        let restored_behaviors = batch.behavior_count();
        assert_eq!(restored_behaviors, rm.behavior_count(), "behaviors survive the trip");
        let mut fresh = ResourceManager::new(0);
        restore_into(&mut fresh, batch);
        assert_eq!(fresh.len(), 20);
        assert_eq!(fresh.behavior_count(), restored_behaviors);
        // Global ids still resolve (constant across restore).
        let gid = rm.iter().next().unwrap().global_id;
        assert!(fresh.get_by_global(gid).is_some());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_header_rejected() {
        let dir = tmpdir("corrupt");
        let path = dir.join("rank_0000_iter_00000000.tacp");
        std::fs::write(&path, b"not a checkpoint at all........").unwrap();
        assert!(read_checkpoint(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn truncated_payload_rejected() {
        let dir = tmpdir("trunc");
        let mut rm = ResourceManager::new(1);
        populate(&mut rm, 10);
        let path = write_checkpoint(&dir, 1, 2, &mut rm).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 16]).unwrap();
        assert!(read_checkpoint(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bit_rot_anywhere_is_rejected_by_the_crc() {
        let dir = tmpdir("bitrot");
        let mut rm = ResourceManager::new(2);
        populate(&mut rm, 12);
        let path = write_checkpoint(&dir, 2, 9, &mut rm).unwrap();
        let clean = std::fs::read(&path).unwrap();
        // Flip one bit at a few positions spread over header and payload.
        for pos in [9usize, HEADER_BYTES + 1, clean.len() / 2, clean.len() - 1] {
            let mut bad = clean.clone();
            bad[pos] ^= 0x10;
            std::fs::write(&path, &bad).unwrap();
            assert!(read_checkpoint(&path).is_err(), "flip at {pos} must be detected");
        }
        std::fs::write(&path, &clean).unwrap();
        assert!(read_checkpoint(&path).is_ok(), "clean bytes still restore");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn restore_latest_valid_skips_corrupt_newest() {
        let dir = tmpdir("latest");
        let mut rm = ResourceManager::new(0);
        populate(&mut rm, 8);
        write_checkpoint(&dir, 0, 10, &mut rm).unwrap();
        populate(&mut rm, 4); // 12 agents at iteration 20
        let newest = write_checkpoint(&dir, 0, 20, &mut rm).unwrap();
        // Newest valid → picked.
        let (info, agents) = restore_latest_valid(&dir, 0).unwrap().unwrap();
        assert_eq!((info.iteration, agents.len()), (20, 12));
        // Corrupt the newest → falls back to iteration 10.
        let mut bytes = std::fs::read(&newest).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&newest, &bytes).unwrap();
        let (info, agents) = restore_latest_valid(&dir, 0).unwrap().unwrap();
        assert_eq!((info.iteration, agents.len()), (10, 8));
        // A stray .tmp from a crashed write is never considered.
        std::fs::remove_file(&newest).unwrap();
        std::fs::write(dir.join("rank_0000_iter_00000030.tacp.tmp"), b"torn").unwrap();
        let (info, _) = restore_latest_valid(&dir, 0).unwrap().unwrap();
        assert_eq!(info.iteration, 10);
        // Other ranks' files don't leak in.
        assert!(restore_latest_valid(&dir, 5).unwrap().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn find_checkpoints_filters_by_iteration() {
        let dir = tmpdir("find");
        let mut rm0 = ResourceManager::new(0);
        let mut rm1 = ResourceManager::new(1);
        populate(&mut rm0, 5);
        populate(&mut rm1, 5);
        write_checkpoint(&dir, 0, 7, &mut rm0).unwrap();
        write_checkpoint(&dir, 1, 7, &mut rm1).unwrap();
        write_checkpoint(&dir, 0, 8, &mut rm0).unwrap();
        let found = find_checkpoints(&dir, 7).unwrap();
        assert_eq!(found.len(), 2);
        assert!(found[0].to_str().unwrap().contains("rank_0000"));
        assert!(found[1].to_str().unwrap().contains("rank_0001"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn distributed_checkpoint_restores_whole_population() {
        // 2 ranks checkpoint; restore the union into one manager (the
        // "resume on different rank count" capability).
        let dir = tmpdir("dist");
        let mut rm0 = ResourceManager::new(0);
        let mut rm1 = ResourceManager::new(1);
        populate(&mut rm0, 30);
        populate(&mut rm1, 25);
        write_checkpoint(&dir, 0, 3, &mut rm0).unwrap();
        write_checkpoint(&dir, 1, 3, &mut rm1).unwrap();
        let mut merged = ResourceManager::new(0);
        for p in find_checkpoints(&dir, 3).unwrap() {
            let (_, agents) = read_checkpoint(&p).unwrap();
            restore_into(&mut merged, agents);
        }
        assert_eq!(merged.len(), 55);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Write checkpoints for `ranks` ranks at `iteration` plus the
    /// matching manifest, populating each rank with `base + 10*r` agents.
    fn checkpoint_round(dir: &Path, ranks: u32, iteration: u64, base: usize) {
        let mut entries = Vec::new();
        for r in 0..ranks {
            let mut rm = ResourceManager::new(r);
            populate(&mut rm, base + 10 * r as usize);
            let path = write_checkpoint(dir, r, iteration, &mut rm).unwrap();
            let (info, crc) = verify_checkpoint(&path).unwrap();
            entries.push(ManifestEntry { rank: r, agents: info.agents, crc });
        }
        write_manifest(dir, &Manifest { iteration, rank_count: ranks, ranks: entries })
            .unwrap();
    }

    #[test]
    fn manifest_round_trip_and_validation() {
        let dir = tmpdir("manifest_rt");
        let m = Manifest {
            iteration: 42,
            rank_count: 3,
            // Non-prefix rank set on purpose: v2's reason to exist.
            ranks: vec![
                ManifestEntry { rank: 0, agents: 10, crc: 0xDEAD_BEEF },
                ManifestEntry { rank: 2, agents: 0, crc: 0 },
                ManifestEntry { rank: 7, agents: u64::MAX, crc: 0xFFFF_FFFF },
            ],
        };
        let path = write_manifest(&dir, &m).unwrap();
        assert_eq!(read_manifest(&path).unwrap(), m);
        // Any single-bit flip is rejected with InvalidData, never a panic.
        let clean = std::fs::read(&path).unwrap();
        for pos in 0..clean.len() {
            let mut bad = clean.clone();
            bad[pos] ^= 0x01;
            std::fs::write(&path, &bad).unwrap();
            let err = read_manifest(&path).unwrap_err();
            assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "flip at {pos}");
        }
        // Truncations too.
        for len in 0..clean.len() {
            std::fs::write(&path, &clean[..len]).unwrap();
            let err = read_manifest(&path).unwrap_err();
            assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "truncated to {len}");
        }
        std::fs::write(&path, &clean).unwrap();
        assert_eq!(read_manifest(&path).unwrap(), m);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn agreement_walks_back_past_incomplete_rounds() {
        let dir = tmpdir("agree");
        assert!(latest_agreed_iteration(&dir).unwrap().is_none());
        checkpoint_round(&dir, 2, 10, 8);
        checkpoint_round(&dir, 2, 20, 12);
        // Both rounds complete: newest wins.
        let m = latest_agreed_iteration(&dir).unwrap().unwrap();
        assert_eq!((m.iteration, m.rank_count), (20, 2));
        assert_eq!(m.ranks[0].agents, 12);
        assert_eq!(m.ranks[1].agents, 22);
        // Corrupt rank 1's newest checkpoint: agreement falls back to 10
        // even though rank 0's iteration-20 file is fine.
        let victim = dir.join(checkpoint_name(1, 20));
        let mut bytes = std::fs::read(&victim).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&victim, &bytes).unwrap();
        let m = latest_agreed_iteration(&dir).unwrap().unwrap();
        assert_eq!(m.iteration, 10);
        // A manifest referencing a missing file (stale rank count: 3
        // ranks claimed, 2 on disk) is skipped, not fatal.
        write_manifest(
            &dir,
            &Manifest {
                iteration: 30,
                rank_count: 3,
                ranks: (0..3)
                    .map(|r| ManifestEntry { rank: r, agents: 1, crc: 2 })
                    .collect(),
            },
        )
        .unwrap();
        assert_eq!(latest_agreed_iteration(&dir).unwrap().unwrap().iteration, 10);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn divergent_restore_regression_all_ranks_roll_back_together() {
        // The PR 6 bug: rank 0's newest checkpoint corrupt, rank 1's
        // fine — per-rank newest-valid would restore rank 0 at iteration
        // 10 and rank 1 at iteration 20. With manifests, both roll back
        // to 10 together.
        let dir = tmpdir("divergent");
        checkpoint_round(&dir, 2, 10, 8);
        checkpoint_round(&dir, 2, 20, 12);
        let victim = dir.join(checkpoint_name(0, 20));
        let mut bytes = std::fs::read(&victim).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x40;
        std::fs::write(&victim, &bytes).unwrap();
        let (i0, _) = restore_latest_valid(&dir, 0).unwrap().unwrap();
        let (i1, _) = restore_latest_valid(&dir, 1).unwrap().unwrap();
        assert_eq!(i0.iteration, 10, "rank 0 falls back past its torn file");
        assert_eq!(
            i1.iteration, 10,
            "rank 1 must roll back WITH rank 0, not restore its own newest"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn restore_resharded_covers_everything_exactly_once_and_is_deterministic() {
        use crate::space::{Aabb, PartitionGrid};
        let dir = tmpdir("reshard");
        // 4 ranks checkpoint 200 agents total at iteration 6.
        let mut entries = Vec::new();
        let mut want_keys = Vec::new();
        for r in 0..4u32 {
            let mut rm = ResourceManager::new(r);
            for i in 0..50usize {
                let pos = Vec3::new(
                    (r as f64) * 15.0 + (i % 7) as f64,
                    (i % 11) as f64 * 5.0,
                    (i % 5) as f64 * 9.0,
                );
                rm.add(Agent::cell(pos, 4.0, CellType::B));
            }
            let path = write_checkpoint(&dir, r, 6, &mut rm).unwrap();
            want_keys.extend(rm.iter().map(|a| (a.global_id, a.position.x.to_bits())));
            let (info, crc) = verify_checkpoint(&path).unwrap();
            entries.push(ManifestEntry { rank: r, agents: info.agents, crc });
        }
        write_manifest(&dir, &Manifest { iteration: 6, rank_count: 4, ranks: entries })
            .unwrap();
        let whole = Aabb::new(Vec3::ZERO, Vec3::splat(60.0));
        // Every survivor computes the same ownership and together they
        // partition the full population.
        let mut got_keys = Vec::new();
        let mut owner_maps: Vec<Vec<u32>> = Vec::new();
        for me in 0..3u32 {
            let mut grid = PartitionGrid::new(whole, 10.0);
            let out = restore_resharded(&dir, 6, 4, 3, &mut grid, me).unwrap();
            assert_eq!(out.total_agents, 200);
            got_keys
                .extend(out.agents.iter().map(|(a, _)| (a.global_id, a.position.x.to_bits())));
            owner_maps.push(grid.owners().to_vec());
        }
        assert_eq!(owner_maps[0], owner_maps[1]);
        assert_eq!(owner_maps[1], owner_maps[2]);
        assert!(owner_maps[0].iter().all(|&o| o < 3), "owners limited to survivors");
        want_keys.sort_unstable();
        got_keys.sort_unstable();
        assert_eq!(want_keys, got_keys, "every agent owned exactly once");
        // Running the same restore twice is bit-stable.
        let mut grid = PartitionGrid::new(whole, 10.0);
        let again = restore_resharded(&dir, 6, 4, 3, &mut grid, 1).unwrap();
        let mut grid2 = PartitionGrid::new(whole, 10.0);
        let again2 = restore_resharded(&dir, 6, 4, 3, &mut grid2, 1).unwrap();
        let key = |a: &Agent| (a.global_id, a.position.x.to_bits(), a.position.y.to_bits());
        assert_eq!(
            again.agents.iter().map(|(a, _)| key(a)).collect::<Vec<_>>(),
            again2.agents.iter().map(|(a, _)| key(a)).collect::<Vec<_>>()
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn v1_dense_manifests_still_read() {
        // Hand-assemble a version-1 manifest (12-byte entries, rank
        // implied by index) and check the v2 reader parses it with the
        // implied prefix rank ids.
        let dir = tmpdir("manifest_v1");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MANIFEST_MAGIC.to_le_bytes());
        bytes.extend_from_slice(&1u32.to_le_bytes()); // version 1
        bytes.extend_from_slice(&2u32.to_le_bytes()); // rank_count
        bytes.extend_from_slice(&0u32.to_le_bytes());
        bytes.extend_from_slice(&99u64.to_le_bytes()); // iteration
        for (agents, crc) in [(7u64, 0x1111u32), (9, 0x2222)] {
            bytes.extend_from_slice(&agents.to_le_bytes());
            bytes.extend_from_slice(&crc.to_le_bytes());
        }
        let crc = Crc32::new().update(&bytes).finalize();
        bytes.extend_from_slice(&crc.to_le_bytes());
        let path = dir.join(manifest_name(99));
        std::fs::write(&path, &bytes).unwrap();
        let m = read_manifest(&path).unwrap();
        assert_eq!((m.iteration, m.rank_count), (99, 2));
        assert_eq!(m.rank_ids(), vec![0, 1]);
        assert_eq!(m.ranks[0], ManifestEntry { rank: 0, agents: 7, crc: 0x1111 });
        assert_eq!(m.ranks[1], ManifestEntry { rank: 1, agents: 9, crc: 0x2222 });
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn mapped_reshard_handles_a_non_prefix_survivor_set() {
        use crate::space::{Aabb, PartitionGrid};
        let dir = tmpdir("reshard_mapped");
        // 4 ranks checkpoint at iteration 5; rank 1 then dies, so the
        // survivors are the non-prefix set {0, 2, 3}.
        let mut want_keys = Vec::new();
        for r in 0..4u32 {
            let mut rm = ResourceManager::new(r);
            for i in 0..40usize {
                let pos = Vec3::new(
                    (r as f64) * 15.0 + (i % 7) as f64,
                    (i % 11) as f64 * 5.0,
                    (i % 5) as f64 * 9.0,
                );
                rm.add(Agent::cell(pos, 4.0, CellType::B));
            }
            write_checkpoint(&dir, r, 5, &mut rm).unwrap();
            want_keys.extend(rm.iter().map(|a| (a.global_id, a.position.x.to_bits())));
        }
        let whole = Aabb::new(Vec3::ZERO, Vec3::splat(60.0));
        let survivors = [0u32, 2, 3];
        let old_ids = [0u32, 1, 2, 3];
        let mut got_keys = Vec::new();
        let mut owner_maps: Vec<Vec<u32>> = Vec::new();
        for &me in &survivors {
            let mut grid = PartitionGrid::new(whole, 10.0);
            let out =
                restore_resharded_mapped(&dir, 5, &old_ids, &survivors, &mut grid, me).unwrap();
            assert_eq!(out.total_agents, 160);
            got_keys
                .extend(out.agents.iter().map(|(a, _)| (a.global_id, a.position.x.to_bits())));
            owner_maps.push(grid.owners().to_vec());
        }
        assert_eq!(owner_maps[0], owner_maps[1]);
        assert_eq!(owner_maps[1], owner_maps[2]);
        // The dead rank owns nothing; every box lands on a survivor.
        assert!(owner_maps[0].iter().all(|o| survivors.contains(o)));
        // The dead rank's agents were adopted: exactly-once coverage of
        // the full 4-rank population, rank 1's included.
        want_keys.sort_unstable();
        got_keys.sort_unstable();
        assert_eq!(want_keys, got_keys);
        std::fs::remove_dir_all(&dir).ok();
    }
}
