//! Checkpoint / restore (the paper's backup path, §2.5).
//!
//! BioDynaMo/TeraAgent can back up whole simulations to disk and resume
//! them; in the distributed engine this is also where local→global
//! identifier translation happens ("if the agent ... is written to disk
//! as part of a backup or checkpoint"). A checkpoint is one TA IO message
//! per rank plus a small header (iteration, rank, agent count, payload
//! CRC) — the same serialization path as the wire, so the format is
//! exercised end-to-end.
//!
//! Checkpoints are the last rung of the recovery ladder
//! (retry → resync → restore), so they are written to survive the very
//! failures they guard against: each file lands via `.tmp` + atomic
//! rename (a crash mid-write leaves the previous checkpoint intact, never
//! a half-written current one), and the header carries a CRC32 over
//! header fields + payload so a torn or bit-rotted file is rejected on
//! read. [`restore_latest_valid`] walks a rank's checkpoints newest-first
//! and returns the first one that passes validation.

use crate::core::agent::Agent;
use crate::core::resource_manager::ResourceManager;
use crate::io::buffer::AlignedBuf;
use crate::io::ta_io;
use crate::util::crc32::Crc32;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

const MAGIC: u32 = 0x5441_4350; // "TACP"
/// v2: 32-byte header ending in a CRC32 over bytes 0..28 + payload.
const VERSION: u32 = 2;
const HEADER_BYTES: usize = 32;

/// Checkpoint metadata.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CheckpointInfo {
    pub rank: u32,
    pub iteration: u64,
    pub agents: u64,
}

/// Write one rank's agents to `<dir>/rank_<rank>_iter_<iteration>.tacp`.
/// Global-id translation happens here: every agent gets a global id if it
/// does not have one yet (§2.5).
///
/// The bytes are staged in a `.tmp` sibling and atomically renamed into
/// place, so a crash mid-write can only ever lose the checkpoint being
/// written — never corrupt an existing one under the final name.
pub fn write_checkpoint(
    dir: impl AsRef<Path>,
    rank: u32,
    iteration: u64,
    rm: &mut ResourceManager,
) -> std::io::Result<PathBuf> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir)?;
    let ids = rm.ids();
    for id in &ids {
        rm.ensure_global_id(*id);
    }
    let agents: Vec<&Agent> = ids.iter().map(|id| rm.get(*id).expect("id from rm.ids()")).collect();
    let payload = ta_io::serialize(agents.iter().copied());
    let mut head = [0u8; HEADER_BYTES];
    head[0..4].copy_from_slice(&MAGIC.to_le_bytes());
    head[4..8].copy_from_slice(&VERSION.to_le_bytes());
    head[8..12].copy_from_slice(&rank.to_le_bytes());
    head[12..20].copy_from_slice(&iteration.to_le_bytes());
    head[20..28].copy_from_slice(&(agents.len() as u64).to_le_bytes());
    let crc = Crc32::new().update(&head[..28]).update(payload.as_slice()).finalize();
    head[28..32].copy_from_slice(&crc.to_le_bytes());
    let path = dir.join(format!("rank_{rank:04}_iter_{iteration:08}.tacp"));
    let tmp = dir.join(format!("rank_{rank:04}_iter_{iteration:08}.tacp.tmp"));
    {
        let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
        f.write_all(&head)?;
        f.write_all(payload.as_slice())?;
        f.flush()?;
        f.into_inner().map_err(|e| e.into_error())?.sync_all()?;
    }
    std::fs::rename(&tmp, &path)?;
    Ok(path)
}

/// Read a checkpoint file back into (info, agents). Rejects anything that
/// fails validation — wrong magic/version, CRC mismatch (torn write, bit
/// rot), unparsable payload, or an agent count disagreeing with the
/// header — with `InvalidData`, so callers can fall back to an older
/// checkpoint ([`restore_latest_valid`]).
pub fn read_checkpoint(path: impl AsRef<Path>) -> std::io::Result<(CheckpointInfo, Vec<Agent>)> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut head = [0u8; HEADER_BYTES];
    f.read_exact(&mut head)?;
    let magic = u32::from_le_bytes(head[0..4].try_into().expect("fixed slice"));
    let version = u32::from_le_bytes(head[4..8].try_into().expect("fixed slice"));
    if magic != MAGIC || version != VERSION {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("bad checkpoint header: magic={magic:#x} version={version}"),
        ));
    }
    let info = CheckpointInfo {
        rank: u32::from_le_bytes(head[8..12].try_into().expect("fixed slice")),
        iteration: u64::from_le_bytes(head[12..20].try_into().expect("fixed slice")),
        agents: u64::from_le_bytes(head[20..28].try_into().expect("fixed slice")),
    };
    let stored_crc = u32::from_le_bytes(head[28..32].try_into().expect("fixed slice"));
    let mut payload = Vec::new();
    f.read_to_end(&mut payload)?;
    let actual_crc = Crc32::new().update(&head[..28]).update(&payload).finalize();
    if actual_crc != stored_crc {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("checkpoint CRC mismatch: stored {stored_crc:#10x} actual {actual_crc:#10x}"),
        ));
    }
    let view = ta_io::TaView::parse(AlignedBuf::from_bytes(&payload))
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))?;
    let agents = view.materialize_all();
    if agents.len() as u64 != info.agents {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("agent count mismatch: header {} payload {}", info.agents, agents.len()),
        ));
    }
    Ok((info, agents))
}

/// Restore agents into a fresh ResourceManager (fresh local ids; global
/// ids preserved — the constant identifier of §2.5).
pub fn restore_into(rm: &mut ResourceManager, agents: Vec<Agent>) {
    for a in agents {
        rm.add(a);
    }
}

/// List checkpoint files for an iteration, ordered by rank.
pub fn find_checkpoints(dir: impl AsRef<Path>, iteration: u64) -> std::io::Result<Vec<PathBuf>> {
    let suffix = format!("_iter_{iteration:08}.tacp");
    let mut out: Vec<PathBuf> = std::fs::read_dir(dir)?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.file_name().and_then(|n| n.to_str()).is_some_and(|n| n.ends_with(&suffix)))
        .collect();
    out.sort();
    Ok(out)
}

/// Last-resort recovery: scan `dir` for this rank's checkpoints, newest
/// iteration first, and return the first one that passes full validation
/// (magic, version, CRC, payload parse, agent count). Invalid or torn
/// files are skipped, not fatal — that is the point of keeping more than
/// one. Returns `Ok(None)` when no valid checkpoint exists.
pub fn restore_latest_valid(
    dir: impl AsRef<Path>,
    rank: u32,
) -> std::io::Result<Option<(CheckpointInfo, Vec<Agent>)>> {
    let prefix = format!("rank_{rank:04}_iter_");
    let mut candidates: Vec<PathBuf> = std::fs::read_dir(dir)?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with(&prefix) && n.ends_with(".tacp"))
        })
        .collect();
    // Names embed a zero-padded iteration, so lexicographic order is
    // iteration order; walk newest → oldest.
    candidates.sort();
    for path in candidates.iter().rev() {
        if let Ok((info, agents)) = read_checkpoint(path) {
            return Ok(Some((info, agents)));
        }
    }
    Ok(None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::agent::{CellType, SirState};
    use crate::util::Vec3;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("teraagent_ckpt_{name}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn populate(rm: &mut ResourceManager, n: usize) {
        for i in 0..n {
            let pos = Vec3::new(i as f64, 2.0 * i as f64, -(i as f64));
            let a = match i % 3 {
                0 => Agent::cell(pos, 5.0, CellType::B),
                1 => Agent::person(pos, SirState::Infected),
                _ => Agent::tumor_cell(pos, 3.0),
            };
            rm.add(a);
        }
    }

    #[test]
    fn round_trip_preserves_agents_and_assigns_global_ids() {
        let dir = tmpdir("rt");
        let mut rm = ResourceManager::new(3);
        populate(&mut rm, 50);
        let path = write_checkpoint(&dir, 3, 17, &mut rm).unwrap();
        // Translation happened: every agent now has a global id.
        assert!(rm.iter().all(|a| a.global_id.is_set()));
        let (info, agents) = read_checkpoint(&path).unwrap();
        assert_eq!(info, CheckpointInfo { rank: 3, iteration: 17, agents: 50 });
        assert_eq!(agents.len(), 50);
        // Same multiset of (global id, position, kind).
        let key = |a: &Agent| (a.global_id, a.position.x.to_bits(), a.kind.class_id());
        let mut want: Vec<_> = rm.iter().map(key).collect();
        let mut got: Vec<_> = agents.iter().map(key).collect();
        want.sort();
        got.sort();
        assert_eq!(want, got);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn restore_into_fresh_manager() {
        let dir = tmpdir("restore");
        let mut rm = ResourceManager::new(0);
        populate(&mut rm, 20);
        let path = write_checkpoint(&dir, 0, 5, &mut rm).unwrap();
        let (_, agents) = read_checkpoint(&path).unwrap();
        let mut fresh = ResourceManager::new(0);
        restore_into(&mut fresh, agents);
        assert_eq!(fresh.len(), 20);
        // Global ids still resolve (constant across restore).
        let gid = rm.iter().next().unwrap().global_id;
        assert!(fresh.get_by_global(gid).is_some());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_header_rejected() {
        let dir = tmpdir("corrupt");
        let path = dir.join("rank_0000_iter_00000000.tacp");
        std::fs::write(&path, b"not a checkpoint at all........").unwrap();
        assert!(read_checkpoint(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn truncated_payload_rejected() {
        let dir = tmpdir("trunc");
        let mut rm = ResourceManager::new(1);
        populate(&mut rm, 10);
        let path = write_checkpoint(&dir, 1, 2, &mut rm).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 16]).unwrap();
        assert!(read_checkpoint(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bit_rot_anywhere_is_rejected_by_the_crc() {
        let dir = tmpdir("bitrot");
        let mut rm = ResourceManager::new(2);
        populate(&mut rm, 12);
        let path = write_checkpoint(&dir, 2, 9, &mut rm).unwrap();
        let clean = std::fs::read(&path).unwrap();
        // Flip one bit at a few positions spread over header and payload.
        for pos in [9usize, HEADER_BYTES + 1, clean.len() / 2, clean.len() - 1] {
            let mut bad = clean.clone();
            bad[pos] ^= 0x10;
            std::fs::write(&path, &bad).unwrap();
            assert!(read_checkpoint(&path).is_err(), "flip at {pos} must be detected");
        }
        std::fs::write(&path, &clean).unwrap();
        assert!(read_checkpoint(&path).is_ok(), "clean bytes still restore");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn restore_latest_valid_skips_corrupt_newest() {
        let dir = tmpdir("latest");
        let mut rm = ResourceManager::new(0);
        populate(&mut rm, 8);
        write_checkpoint(&dir, 0, 10, &mut rm).unwrap();
        populate(&mut rm, 4); // 12 agents at iteration 20
        let newest = write_checkpoint(&dir, 0, 20, &mut rm).unwrap();
        // Newest valid → picked.
        let (info, agents) = restore_latest_valid(&dir, 0).unwrap().unwrap();
        assert_eq!((info.iteration, agents.len()), (20, 12));
        // Corrupt the newest → falls back to iteration 10.
        let mut bytes = std::fs::read(&newest).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&newest, &bytes).unwrap();
        let (info, agents) = restore_latest_valid(&dir, 0).unwrap().unwrap();
        assert_eq!((info.iteration, agents.len()), (10, 8));
        // A stray .tmp from a crashed write is never considered.
        std::fs::remove_file(&newest).unwrap();
        std::fs::write(dir.join("rank_0000_iter_00000030.tacp.tmp"), b"torn").unwrap();
        let (info, _) = restore_latest_valid(&dir, 0).unwrap().unwrap();
        assert_eq!(info.iteration, 10);
        // Other ranks' files don't leak in.
        assert!(restore_latest_valid(&dir, 5).unwrap().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn find_checkpoints_filters_by_iteration() {
        let dir = tmpdir("find");
        let mut rm0 = ResourceManager::new(0);
        let mut rm1 = ResourceManager::new(1);
        populate(&mut rm0, 5);
        populate(&mut rm1, 5);
        write_checkpoint(&dir, 0, 7, &mut rm0).unwrap();
        write_checkpoint(&dir, 1, 7, &mut rm1).unwrap();
        write_checkpoint(&dir, 0, 8, &mut rm0).unwrap();
        let found = find_checkpoints(&dir, 7).unwrap();
        assert_eq!(found.len(), 2);
        assert!(found[0].to_str().unwrap().contains("rank_0000"));
        assert!(found[1].to_str().unwrap().contains("rank_0001"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn distributed_checkpoint_restores_whole_population() {
        // 2 ranks checkpoint; restore the union into one manager (the
        // "resume on different rank count" capability).
        let dir = tmpdir("dist");
        let mut rm0 = ResourceManager::new(0);
        let mut rm1 = ResourceManager::new(1);
        populate(&mut rm0, 30);
        populate(&mut rm1, 25);
        write_checkpoint(&dir, 0, 3, &mut rm0).unwrap();
        write_checkpoint(&dir, 1, 3, &mut rm1).unwrap();
        let mut merged = ResourceManager::new(0);
        for p in find_checkpoints(&dir, 3).unwrap() {
            let (_, agents) = read_checkpoint(&p).unwrap();
            restore_into(&mut merged, agents);
        }
        assert_eq!(merged.len(), 55);
        std::fs::remove_dir_all(&dir).ok();
    }
}
