//! Checkpoint / restore (the paper's backup path, §2.5).
//!
//! BioDynaMo/TeraAgent can back up whole simulations to disk and resume
//! them; in the distributed engine this is also where local→global
//! identifier translation happens ("if the agent ... is written to disk
//! as part of a backup or checkpoint"). A checkpoint is one TA IO message
//! per rank plus a small header (iteration, rank, agent count) — the same
//! serialization path as the wire, so the format is exercised end-to-end.

use crate::core::agent::Agent;
use crate::core::resource_manager::ResourceManager;
use crate::io::buffer::AlignedBuf;
use crate::io::ta_io;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

const MAGIC: u32 = 0x5441_4350; // "TACP"
const VERSION: u32 = 1;

/// Checkpoint metadata.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CheckpointInfo {
    pub rank: u32,
    pub iteration: u64,
    pub agents: u64,
}

/// Write one rank's agents to `<dir>/rank_<rank>_iter_<iteration>.tacp`.
/// Global-id translation happens here: every agent gets a global id if it
/// does not have one yet (§2.5).
pub fn write_checkpoint(
    dir: impl AsRef<Path>,
    rank: u32,
    iteration: u64,
    rm: &mut ResourceManager,
) -> std::io::Result<PathBuf> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir)?;
    let ids = rm.ids();
    for id in &ids {
        rm.ensure_global_id(*id);
    }
    let agents: Vec<&Agent> = ids.iter().map(|id| rm.get(*id).unwrap()).collect();
    let payload = ta_io::serialize(agents.iter().copied());
    let path = dir.join(format!("rank_{rank:04}_iter_{iteration:08}.tacp"));
    let mut f = std::io::BufWriter::new(std::fs::File::create(&path)?);
    f.write_all(&MAGIC.to_le_bytes())?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&rank.to_le_bytes())?;
    f.write_all(&iteration.to_le_bytes())?;
    f.write_all(&(agents.len() as u64).to_le_bytes())?;
    f.write_all(payload.as_slice())?;
    f.flush()?;
    Ok(path)
}

/// Read a checkpoint file back into (info, agents).
pub fn read_checkpoint(path: impl AsRef<Path>) -> std::io::Result<(CheckpointInfo, Vec<Agent>)> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut head = [0u8; 4 + 4 + 4 + 8 + 8];
    f.read_exact(&mut head)?;
    let magic = u32::from_le_bytes(head[0..4].try_into().unwrap());
    let version = u32::from_le_bytes(head[4..8].try_into().unwrap());
    if magic != MAGIC || version != VERSION {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("bad checkpoint header: magic={magic:#x} version={version}"),
        ));
    }
    let info = CheckpointInfo {
        rank: u32::from_le_bytes(head[8..12].try_into().unwrap()),
        iteration: u64::from_le_bytes(head[12..20].try_into().unwrap()),
        agents: u64::from_le_bytes(head[20..28].try_into().unwrap()),
    };
    let mut payload = Vec::new();
    f.read_to_end(&mut payload)?;
    let view = ta_io::TaView::parse(AlignedBuf::from_bytes(&payload))
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))?;
    let agents = view.materialize_all();
    if agents.len() as u64 != info.agents {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("agent count mismatch: header {} payload {}", info.agents, agents.len()),
        ));
    }
    Ok((info, agents))
}

/// Restore agents into a fresh ResourceManager (fresh local ids; global
/// ids preserved — the constant identifier of §2.5).
pub fn restore_into(rm: &mut ResourceManager, agents: Vec<Agent>) {
    for a in agents {
        rm.add(a);
    }
}

/// List checkpoint files for an iteration, ordered by rank.
pub fn find_checkpoints(dir: impl AsRef<Path>, iteration: u64) -> std::io::Result<Vec<PathBuf>> {
    let suffix = format!("_iter_{iteration:08}.tacp");
    let mut out: Vec<PathBuf> = std::fs::read_dir(dir)?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.file_name().and_then(|n| n.to_str()).is_some_and(|n| n.ends_with(&suffix)))
        .collect();
    out.sort();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::agent::{CellType, SirState};
    use crate::util::Vec3;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("teraagent_ckpt_{name}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn populate(rm: &mut ResourceManager, n: usize) {
        for i in 0..n {
            let pos = Vec3::new(i as f64, 2.0 * i as f64, -(i as f64));
            let a = match i % 3 {
                0 => Agent::cell(pos, 5.0, CellType::B),
                1 => Agent::person(pos, SirState::Infected),
                _ => Agent::tumor_cell(pos, 3.0),
            };
            rm.add(a);
        }
    }

    #[test]
    fn round_trip_preserves_agents_and_assigns_global_ids() {
        let dir = tmpdir("rt");
        let mut rm = ResourceManager::new(3);
        populate(&mut rm, 50);
        let path = write_checkpoint(&dir, 3, 17, &mut rm).unwrap();
        // Translation happened: every agent now has a global id.
        assert!(rm.iter().all(|a| a.global_id.is_set()));
        let (info, agents) = read_checkpoint(&path).unwrap();
        assert_eq!(info, CheckpointInfo { rank: 3, iteration: 17, agents: 50 });
        assert_eq!(agents.len(), 50);
        // Same multiset of (global id, position, kind).
        let key = |a: &Agent| (a.global_id, a.position.x.to_bits(), a.kind.class_id());
        let mut want: Vec<_> = rm.iter().map(key).collect();
        let mut got: Vec<_> = agents.iter().map(key).collect();
        want.sort();
        got.sort();
        assert_eq!(want, got);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn restore_into_fresh_manager() {
        let dir = tmpdir("restore");
        let mut rm = ResourceManager::new(0);
        populate(&mut rm, 20);
        let path = write_checkpoint(&dir, 0, 5, &mut rm).unwrap();
        let (_, agents) = read_checkpoint(&path).unwrap();
        let mut fresh = ResourceManager::new(0);
        restore_into(&mut fresh, agents);
        assert_eq!(fresh.len(), 20);
        // Global ids still resolve (constant across restore).
        let gid = rm.iter().next().unwrap().global_id;
        assert!(fresh.get_by_global(gid).is_some());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_header_rejected() {
        let dir = tmpdir("corrupt");
        let path = dir.join("rank_0000_iter_00000000.tacp");
        std::fs::write(&path, b"not a checkpoint at all........").unwrap();
        assert!(read_checkpoint(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn truncated_payload_rejected() {
        let dir = tmpdir("trunc");
        let mut rm = ResourceManager::new(1);
        populate(&mut rm, 10);
        let path = write_checkpoint(&dir, 1, 2, &mut rm).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 16]).unwrap();
        assert!(read_checkpoint(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn find_checkpoints_filters_by_iteration() {
        let dir = tmpdir("find");
        let mut rm0 = ResourceManager::new(0);
        let mut rm1 = ResourceManager::new(1);
        populate(&mut rm0, 5);
        populate(&mut rm1, 5);
        write_checkpoint(&dir, 0, 7, &mut rm0).unwrap();
        write_checkpoint(&dir, 1, 7, &mut rm1).unwrap();
        write_checkpoint(&dir, 0, 8, &mut rm0).unwrap();
        let found = find_checkpoints(&dir, 7).unwrap();
        assert_eq!(found.len(), 2);
        assert!(found[0].to_str().unwrap().contains("rank_0000"));
        assert!(found[1].to_str().unwrap().contains("rank_0001"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn distributed_checkpoint_restores_whole_population() {
        // 2 ranks checkpoint; restore the union into one manager (the
        // "resume on different rank count" capability).
        let dir = tmpdir("dist");
        let mut rm0 = ResourceManager::new(0);
        let mut rm1 = ResourceManager::new(1);
        populate(&mut rm0, 30);
        populate(&mut rm1, 25);
        write_checkpoint(&dir, 0, 3, &mut rm0).unwrap();
        write_checkpoint(&dir, 1, 3, &mut rm1).unwrap();
        let mut merged = ResourceManager::new(0);
        for p in find_checkpoints(&dir, 3).unwrap() {
            let (_, agents) = read_checkpoint(&p).unwrap();
            restore_into(&mut merged, agents);
        }
        assert_eq!(merged.len(), 55);
        std::fs::remove_dir_all(&dir).ok();
    }
}
