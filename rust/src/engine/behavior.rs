//! Arena-resident behavior execution (the engine phase between mechanics
//! and the model step).
//!
//! Behaviors live in the `ResourceManager`'s flat
//! [`BehaviorArena`](crate::core::resource_manager::BehaviorArena), so
//! executing them is a cache-linear sweep over `(slot, extent)` pairs:
//! [`ResourceManager::behavior_sweep`] hands each closure invocation the
//! shared read-only hot columns plus a *mutable* view of that agent's
//! extent. Parameter updates (trade cooldowns, reputation scores) mutate
//! the arena in place; structural changes — moves, kind transitions,
//! divisions — come back as [`SlotEffect`]s, flattened in slot order
//! regardless of thread count, and are applied serially by the engine.
//! That split is what keeps the phase bit-deterministic at any
//! parallelism: the parallel part only reads shared state and writes
//! disjoint extents, while everything order-sensitive happens on the rank
//! thread in slot order.
//!
//! Determinism across thread counts and transports also requires the
//! per-agent randomness to be independent of slot index and sweep
//! schedule: each slot draws from an [`Rng`] stream keyed by the agent's
//! *global* id and the iteration number (the engine ensures global ids
//! exist before the sweep). Neighbor-dependent behaviors (infection,
//! trade) reduce their neighborhood to an integer count — an
//! order-independent quantity — before consuming any randomness.

use crate::core::agent::{Agent, AgentKind, Behavior, SirState};
use crate::core::ids::{AgentPointer, GlobalId, LocalId};
use crate::core::resource_manager::SweepCols;
use crate::engine::world::AuraStore;
use crate::space::{NeighborSearchGrid, NsgEntry};
use crate::util::{Rng, Vec3};

/// Diameter at which a [`Behavior::Divide`] cell splits.
pub const DIVIDE_DIAMETER: f64 = 8.0;
/// Iterations a citizen rests after a completed trade.
pub const TRADE_REST: u32 = 5;

/// Read-only context shared by every sweep invocation.
pub struct BehaviorCtx<'a> {
    pub iteration: u64,
    pub seed: u64,
    pub nsg: &'a NeighborSearchGrid,
    pub aura: &'a AuraStore,
}

/// Structural changes one agent's behaviors requested this sweep. Applied
/// serially in slot order by the engine (position moves go through the
/// boundary condition and the NSG; a division child inherits the parent's
/// behavior set from the arena).
pub struct SlotEffect {
    pub id: LocalId,
    pub new_pos: Option<Vec3>,
    pub new_diameter: Option<f64>,
    pub new_kind: Option<AgentKind>,
    /// Division child (position not yet boundary-applied). The parent's
    /// post-division diameter rides in `new_diameter`.
    pub child: Option<Agent>,
}

impl SlotEffect {
    fn new(id: LocalId) -> Self {
        SlotEffect { id, new_pos: None, new_diameter: None, new_kind: None, child: None }
    }

    fn is_empty(&self) -> bool {
        self.new_pos.is_none()
            && self.new_diameter.is_none()
            && self.new_kind.is_none()
            && self.child.is_none()
    }
}

/// Stream key for one agent's per-iteration RNG: a pure function of the
/// (constant) global id, so the draw sequence is independent of slot
/// index, thread count and arrival order.
#[inline]
fn gid_key(gid: GlobalId) -> u64 {
    ((gid.rank as u64) << 40) ^ gid.counter
}

/// Execute every behavior of one agent. `bs` is the agent's live arena
/// extent: in-place writes are the parameter-update fast path. Returns
/// `None` when nothing structural changed.
pub fn run_slot(
    id: LocalId,
    cols: &SweepCols<'_>,
    bs: &mut [Behavior],
    ctx: &BehaviorCtx<'_>,
) -> Option<SlotEffect> {
    let i = id.index as usize;
    // Later behaviors of the same agent see earlier ones' writes — the
    // classic sequential-within-agent, parallel-across-agents contract.
    let mut pos = cols.pos[i];
    let mut diam = cols.diam[i];
    let mut kind = cols.kind[i];
    let mut rng = Rng::stream(
        ctx.seed ^ ctx.iteration.wrapping_mul(0x9E37_79B9_7F4A_7C15),
        gid_key(cols.gid[i]),
    );
    let mut eff = SlotEffect::new(id);
    for b in bs.iter_mut() {
        match b {
            Behavior::Growth { rate, max_diameter } => {
                diam = (diam + 0.1 * *rate).min(*max_diameter);
            }
            Behavior::Divide => {
                if diam >= DIVIDE_DIAMETER {
                    // Volume-halving split; the child lands a quarter
                    // diameter away in a random direction.
                    let half = 0.5f64.powf(1.0 / 3.0);
                    let child_diam = diam * half;
                    let dir = random_unit(&mut rng);
                    eff.child = Some(Agent {
                        local_id: LocalId::INVALID,
                        global_id: GlobalId::UNSET,
                        position: pos + dir * (diam * 0.25),
                        diameter: child_diam,
                        kind,
                        neighbor_ref: AgentPointer::NULL,
                    });
                    diam = child_diam;
                }
            }
            Behavior::RandomWalk { speed } => {
                let s = *speed / 3f64.sqrt();
                pos += Vec3::new(rng.normal() * s, rng.normal() * s, rng.normal() * s);
            }
            Behavior::Infection { radius, prob, recovery_iters } => match kind {
                AgentKind::Person { state: SirState::Susceptible, infected_for } => {
                    let n = count_neighbors(ctx, cols, pos, *radius, id, |k| {
                        matches!(k, AgentKind::Person { state: SirState::Infected, .. })
                    });
                    // One draw against the aggregate exposure — the count
                    // is order-independent, so the draw is too.
                    if n > 0 && rng.uniform() < 1.0 - (1.0 - *prob).powi(n as i32) {
                        kind = AgentKind::Person { state: SirState::Infected, infected_for };
                    }
                }
                AgentKind::Person { state: SirState::Infected, infected_for } => {
                    kind = if infected_for + 1 >= *recovery_iters {
                        AgentKind::Person { state: SirState::Recovered, infected_for: 0 }
                    } else {
                        AgentKind::Person {
                            state: SirState::Infected,
                            infected_for: infected_for + 1,
                        }
                    };
                }
                _ => {}
            },
            Behavior::TumorGrowth { cycle_rate, max_diameter } => {
                if let AgentKind::TumorCell { cycle, quiescent } = kind {
                    if !quiescent {
                        let mut c = cycle + *cycle_rate;
                        let mut q = quiescent;
                        if c >= 1.0 {
                            c -= 1.0;
                            diam = (diam * 2f64.powf(1.0 / 3.0)).min(*max_diameter);
                            if diam >= *max_diameter {
                                q = true;
                            }
                        }
                        kind = AgentKind::TumorCell { cycle: c, quiescent: q };
                    }
                }
            }
            Behavior::Trade { radius, gain, cooldown } => {
                if let AgentKind::Citizen { wealth, reputation } = kind {
                    if *cooldown > 0 {
                        // In-place arena write — no effect, no allocation.
                        *cooldown -= 1;
                    } else {
                        let n = count_neighbors(ctx, cols, pos, *radius, id, |k| {
                            matches!(k, AgentKind::Citizen { .. })
                        });
                        if n > 0 {
                            kind = AgentKind::Citizen {
                                wealth: wealth + *gain * n as f64,
                                reputation,
                            };
                            *cooldown = TRADE_REST;
                        }
                    }
                }
            }
            Behavior::Reputation { score, decay } => {
                if let AgentKind::Citizen { wealth, .. } = kind {
                    // Exponential relaxation toward log-wealth; the score
                    // is mirrored into the kind payload so it travels on
                    // the wire with the agent header.
                    *score += *decay * (wealth.max(1.0).ln() - *score);
                    kind = AgentKind::Citizen { wealth, reputation: *score };
                }
            }
        }
    }
    if pos != cols.pos[i] {
        eff.new_pos = Some(pos);
    }
    if diam != cols.diam[i] {
        eff.new_diameter = Some(diam);
    }
    if kind != cols.kind[i] {
        eff.new_kind = Some(kind);
    }
    if eff.is_empty() { None } else { Some(eff) }
}

/// Random unit vector (isotropic via normalized Gaussian triple).
fn random_unit(rng: &mut Rng) -> Vec3 {
    let v = Vec3::new(rng.normal(), rng.normal(), rng.normal());
    let n = (v.x * v.x + v.y * v.y + v.z * v.z).sqrt();
    if n > 1e-12 { v * (1.0 / n) } else { Vec3::new(1.0, 0.0, 0.0) }
}

/// Count neighbors within `radius` matching `pred`. Owned neighbors read
/// their kind from the shared sweep columns (the NSG guarantees live
/// entries), aura neighbors from the aura store's SoA mirror.
fn count_neighbors(
    ctx: &BehaviorCtx<'_>,
    cols: &SweepCols<'_>,
    center: Vec3,
    radius: f64,
    exclude: LocalId,
    pred: impl Fn(&AgentKind) -> bool,
) -> usize {
    let mut n = 0usize;
    ctx.nsg.for_each_neighbor(center, radius, Some(NsgEntry::Owned(exclude)), |entry, _, _| {
        let kind = match entry {
            NsgEntry::Owned(nid) => cols.kind[nid.index as usize],
            NsgEntry::Aura(ai) => ctx.aura.kind(ai),
        };
        if pred(&kind) {
            n += 1;
        }
    });
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::agent::CellType;
    use crate::core::resource_manager::ResourceManager;
    use crate::engine::pool::ThreadPool;
    use crate::space::Aabb;

    fn sweep_once(
        rm: &mut ResourceManager,
        nsg: &NeighborSearchGrid,
        threads: usize,
        iteration: u64,
    ) -> Vec<SlotEffect> {
        let aura = AuraStore::new();
        let ctx = BehaviorCtx { iteration, seed: 42, nsg, aura: &aura };
        let ids = rm.ids();
        for &id in &ids {
            rm.ensure_global_id(id);
        }
        let pool = ThreadPool::new(threads);
        let (effects, _) =
            rm.behavior_sweep(&pool, &ids, |_k, id, cols, bs| run_slot(id, cols, bs, &ctx));
        effects
    }

    #[test]
    fn growth_caps_at_max_and_divide_splits() {
        let whole = Aabb::cube(100.0);
        let nsg = NeighborSearchGrid::new(whole, 10.0);
        let mut rm = ResourceManager::new(0);
        let id = rm.add_with_behaviors(
            Agent::growing_cell(Vec3::new(50.0, 50.0, 50.0), 7.99),
            &[Behavior::Growth { rate: 1.0, max_diameter: 9.0 }, Behavior::Divide],
        );
        let effects = sweep_once(&mut rm, &nsg, 1, 0);
        assert_eq!(effects.len(), 1);
        let eff = &effects[0];
        assert_eq!(eff.id, id);
        // Growth pushed 7.99 past the divide threshold, so the division
        // fired in the same sweep; the parent keeps the child diameter.
        let child = eff.child.as_ref().expect("division fired");
        let half = 0.5f64.powf(1.0 / 3.0);
        let grown = (7.99f64 + 0.1).min(9.0);
        assert_eq!(eff.new_diameter.unwrap(), grown * half);
        assert_eq!(child.diameter, grown * half);
        assert!(matches!(child.kind, AgentKind::GrowingCell { .. }));
    }

    #[test]
    fn trade_counts_citizen_neighbors_and_rests() {
        let whole = Aabb::cube(100.0);
        let mut nsg = NeighborSearchGrid::new(whole, 10.0);
        let mut rm = ResourceManager::new(0);
        let trader = rm.add_with_behaviors(
            Agent::citizen(Vec3::new(50.0, 50.0, 50.0), 100.0),
            &[Behavior::Trade { radius: 5.0, gain: 2.0, cooldown: 0 }],
        );
        nsg.add(NsgEntry::Owned(trader), Vec3::new(50.0, 50.0, 50.0));
        // Two citizen partners in range, one cell (ignored), one citizen
        // out of range.
        for (p, citizen) in [
            (Vec3::new(52.0, 50.0, 50.0), true),
            (Vec3::new(50.0, 52.0, 50.0), true),
            (Vec3::new(50.0, 50.0, 52.0), false),
            (Vec3::new(80.0, 50.0, 50.0), true),
        ] {
            let a = if citizen {
                Agent::citizen(p, 10.0)
            } else {
                Agent::cell(p, 1.0, CellType::A)
            };
            let id = rm.add(a);
            nsg.add(NsgEntry::Owned(id), p);
        }
        let effects = sweep_once(&mut rm, &nsg, 1, 0);
        assert_eq!(effects.len(), 1);
        match effects[0].new_kind.unwrap() {
            AgentKind::Citizen { wealth, .. } => assert_eq!(wealth, 100.0 + 2.0 * 2.0),
            other => panic!("trader stayed a citizen, got {other:?}"),
        }
        // The completed trade armed the cooldown *in the arena*.
        match rm.behaviors(trader).unwrap()[0] {
            Behavior::Trade { cooldown, .. } => assert_eq!(cooldown, TRADE_REST),
            other => panic!("unexpected behavior {other:?}"),
        }
        // Next sweep: resting — cooldown ticks down in place, no effect.
        let effects = sweep_once(&mut rm, &nsg, 1, 1);
        assert!(effects.iter().all(|e| e.id != trader || e.new_kind.is_none()));
        match rm.behaviors(trader).unwrap()[0] {
            Behavior::Trade { cooldown, .. } => assert_eq!(cooldown, TRADE_REST - 1),
            other => panic!("unexpected behavior {other:?}"),
        }
    }

    #[test]
    fn sweep_effects_identical_at_any_thread_count() {
        let whole = Aabb::cube(200.0);
        let mut nsg = NeighborSearchGrid::new(whole, 10.0);
        let build = || {
            let mut rm = ResourceManager::new(0);
            let mut rng = Rng::new(7);
            for i in 0..120usize {
                let p = Vec3::from_array(rng.point_in([5.0; 3], [195.0; 3]));
                match i % 3 {
                    0 => {
                        rm.add_with_behaviors(
                            Agent::citizen(p, 50.0 + i as f64),
                            &[
                                Behavior::RandomWalk { speed: 0.5 },
                                Behavior::Trade { radius: 8.0, gain: 1.0, cooldown: 0 },
                                Behavior::Reputation { score: 0.0, decay: 0.1 },
                            ],
                        );
                    }
                    1 => {
                        rm.add_with_behaviors(
                            Agent::growing_cell(p, 6.0 + (i % 5) as f64),
                            &[
                                Behavior::Growth { rate: 5.0, max_diameter: 12.0 },
                                Behavior::Divide,
                            ],
                        );
                    }
                    _ => {
                        rm.add(Agent::cell(p, 2.0, CellType::B));
                    }
                }
            }
            rm
        };
        // Shared NSG over the common position set.
        {
            let rm = build();
            for id in rm.ids() {
                nsg.add(NsgEntry::Owned(id), rm.col_position(id.index));
            }
        }
        let key = |e: &SlotEffect| {
            (
                e.id.pack(),
                e.new_pos.map(|p| [p.x.to_bits(), p.y.to_bits(), p.z.to_bits()]),
                e.new_diameter.map(f64::to_bits),
                e.child.map(|c| c.diameter.to_bits()),
            )
        };
        let mut rm1 = build();
        let base: Vec<_> = sweep_once(&mut rm1, &nsg, 1, 3).iter().map(key).collect();
        assert!(!base.is_empty());
        for threads in [2usize, 8] {
            let mut rm = build();
            let got: Vec<_> = sweep_once(&mut rm, &nsg, threads, 3).iter().map(key).collect();
            assert_eq!(got, base, "{threads} threads");
            // Arena contents (in-place mutations) agree too.
            for (a, b) in rm.ids().iter().zip(rm1.ids().iter()) {
                assert_eq!(rm.behaviors(*a), rm1.behaviors(*b));
            }
        }
    }
}
