//! The `teraagent` launcher binary.
//!
//! `teraagent run --sim cell_clustering --ranks 4 --threads 2 --pjrt`
//! runs a benchmark simulation under the configured parallelization mode
//! and prints the aggregated report — the same engine the examples and
//! benches drive programmatically.

use teraagent::cli;
use teraagent::models;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let parsed = match cli::parse(&args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", cli::usage());
            std::process::exit(2);
        }
    };
    match parsed.command.as_str() {
        "help" | "--help" | "-h" => print!("{}", cli::usage()),
        "info" => info(),
        "run" => run(&parsed.flags),
        other => {
            eprintln!("error: unknown command {other:?}\n\n{}", cli::usage());
            std::process::exit(2);
        }
    }
}

fn info() {
    println!("teraagent v{}", teraagent::VERSION);
    match teraagent::runtime::PjrtRuntime::cpu() {
        Ok(rt) => println!(
            "PJRT: platform={} devices={}",
            rt.platform(),
            rt.device_count()
        ),
        Err(e) => println!("PJRT: unavailable ({e})"),
    }
    for name in models::BENCHMARKS {
        println!("model: {name}");
    }
}

fn run(flags: &std::collections::BTreeMap<String, String>) {
    let cfg = match cli::config_from_flags(flags) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    println!(
        "running {} | agents={} iterations={} mode={} ranks={} threads={} \
         serializer={} compression={} network={} pjrt={}",
        cfg.name,
        cfg.num_agents,
        cfg.iterations,
        cfg.mode.name(),
        cfg.mode.ranks(),
        cfg.mode.threads_per_rank(),
        cfg.serializer.name(),
        cfg.compression.name(),
        cfg.network.name,
        cfg.use_pjrt,
    );
    let result = match models::run_by_name(&cfg) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    println!("{}", result.report.render());
    if !result.stat_names.is_empty() {
        println!("stats ({}):", result.stat_names.join(", "));
        let n = result.stats_history.len();
        for (i, row) in result.stats_history.iter().enumerate() {
            if i < 3 || i >= n.saturating_sub(3) {
                let vals: Vec<String> = row.iter().map(|v| format!("{v:.2}")).collect();
                println!("  iter {i:>4}: {}", vals.join("  "));
            } else if i == 3 {
                println!("  ...");
            }
        }
    }
    println!(
        "final agents: {} | updates/s/core: {:.3e} | pjrt: {}",
        result.final_agents,
        result.report.updates_per_sec_per_core(cfg.mode.cores()),
        result.used_pjrt,
    );
}
