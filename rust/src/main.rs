//! The `teraagent` launcher binary.
//!
//! `teraagent run --sim cell_clustering --ranks 4 --threads 2 --pjrt`
//! runs a benchmark simulation under the configured parallelization mode
//! and prints the aggregated report — the same engine the examples and
//! benches drive programmatically.

use teraagent::cli;
use teraagent::models;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let parsed = match cli::parse(&args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", cli::usage());
            std::process::exit(2);
        }
    };
    match parsed.command.as_str() {
        "help" | "--help" | "-h" => print!("{}", cli::usage()),
        "info" => info(),
        "run" => run(&parsed.flags),
        // Hidden: one rank of a multiprocess run (spawned by the
        // launcher, never invoked by hand).
        "_rank" => rank_child(&parsed.flags),
        other => {
            eprintln!("error: unknown command {other:?}\n\n{}", cli::usage());
            std::process::exit(2);
        }
    }
}

/// The `_rank` child: connect the multiprocess transport, run one rank to
/// completion, write the binary outcome file the parent collects.
fn rank_child(flags: &std::collections::BTreeMap<String, String>) {
    use teraagent::comm::FaultPlan;
    use teraagent::engine::launcher;

    fn fail(msg: String) -> ! {
        eprintln!("_rank error: {msg}");
        std::process::exit(3);
    }
    let get = |k: &str| -> &String {
        flags.get(k).unwrap_or_else(|| fail(format!("--{k} is required")))
    };
    let rendezvous = std::path::PathBuf::from(get("rendezvous"));
    let rank: u32 = get("rank").parse().unwrap_or_else(|_| fail("--rank: bad number".into()));
    let size: usize =
        get("size").parse().unwrap_or_else(|_| fail("--size: bad number".into()));
    let config_text = std::fs::read_to_string(get("config-file"))
        .unwrap_or_else(|e| fail(format!("--config-file: {e}")));
    let cfg = teraagent::config::SimConfig::from_toml(&config_text)
        .unwrap_or_else(|e| fail(format!("config: {e}")));
    if cfg.mode.ranks() != size {
        fail(format!("--size {size} disagrees with config ranks {}", cfg.mode.ranks()));
    }
    if rank as usize >= size {
        fail(format!("--rank {rank} out of range for size {size}"));
    }
    // Rebuild the scripted fault plan (if any) from the --chaos-* flags
    // the parent serialized.
    let getf = |k: &str| -> Option<f64> {
        flags.get(k).map(|v| {
            v.parse::<f64>().unwrap_or_else(|_| fail(format!("--{k}: bad number {v:?}")))
        })
    };
    let geti = |k: &str| -> Option<u64> {
        flags.get(k).map(|v| {
            v.parse::<u64>().unwrap_or_else(|_| fail(format!("--{k}: bad number {v:?}")))
        })
    };
    let has_chaos = flags.keys().any(|k| k.starts_with("chaos-"));
    let chaos = has_chaos.then(|| {
        let mut plan = FaultPlan::none(geti("chaos-seed").unwrap_or(cfg.seed));
        if let Some(p) = getf("chaos-drop") {
            plan = plan.with_drop(p);
        }
        if let Some(p) = getf("chaos-dup") {
            plan = plan.with_duplicate(p);
        }
        if let Some(p) = getf("chaos-flip") {
            plan = plan.with_bit_flip(p);
        }
        if let Some(n) = geti("chaos-max-faults") {
            plan = plan.with_max_faults(n);
        }
        if let Some(k) = geti("chaos-kill-iter") {
            plan = plan.with_kill_at_iteration(k);
        }
        if let Some(spec) = flags.get("chaos-tags") {
            let tags: Vec<u32> = spec
                .split(',')
                .map(|t| {
                    t.parse().unwrap_or_else(|_| fail(format!("--chaos-tags: bad tag {t:?}")))
                })
                .collect();
            plan = plan.with_tags(tags);
        }
        plan
    });
    let killed = chaos.as_ref().and_then(|p| p.kill_at_iteration).is_some();
    let outcome = models::run_rank_by_name(&cfg, rank, &rendezvous, chaos)
        .unwrap_or_else(|e| fail(e));
    let path = rendezvous.join(launcher::outcome_file_name(rank));
    launcher::write_rank_outcome(&path, rank, killed, &outcome)
        .unwrap_or_else(|e| fail(format!("write outcome: {e}")));
}

fn info() {
    println!("teraagent v{}", teraagent::VERSION);
    match teraagent::runtime::PjrtRuntime::cpu() {
        Ok(rt) => println!(
            "PJRT: platform={} devices={}",
            rt.platform(),
            rt.device_count()
        ),
        Err(e) => println!("PJRT: unavailable ({e})"),
    }
    for name in models::BENCHMARKS {
        println!("model: {name}");
    }
}

fn run(flags: &std::collections::BTreeMap<String, String>) {
    let cfg = match cli::config_from_flags(flags) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    println!(
        "running {} | agents={} iterations={} mode={} ranks={} threads={} \
         serializer={} compression={} network={} pjrt={}",
        cfg.name,
        cfg.num_agents,
        cfg.iterations,
        cfg.mode.name(),
        cfg.mode.ranks(),
        cfg.mode.threads_per_rank(),
        cfg.serializer.name(),
        cfg.compression.name(),
        cfg.network.name,
        cfg.use_pjrt,
    );
    let result = match models::run_by_name(&cfg) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    println!("{}", result.report.render());
    if !result.stat_names.is_empty() {
        println!("stats ({}):", result.stat_names.join(", "));
        let n = result.stats_history.len();
        for (i, row) in result.stats_history.iter().enumerate() {
            if i < 3 || i >= n.saturating_sub(3) {
                let vals: Vec<String> = row.iter().map(|v| format!("{v:.2}")).collect();
                println!("  iter {i:>4}: {}", vals.join("  "));
            } else if i == 3 {
                println!("  ...");
            }
        }
    }
    println!(
        "final agents: {} | updates/s/core: {:.3e} | pjrt: {}",
        result.final_agents,
        result.report.updates_per_sec_per_core(cfg.mode.cores()),
        result.used_pjrt,
    );
}
