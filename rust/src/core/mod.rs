//! Agent substrate: identifiers, the agent data model, behaviors, and the
//! per-rank [`ResourceManager`] that owns agent storage.

pub mod agent;
pub mod compact;
pub mod ids;
pub mod resource_manager;

pub use agent::{Agent, AgentKind, Behavior, CellType, SirState};
pub use ids::{AgentPointer, GlobalId, LocalId};
pub use resource_manager::{AgentRefMut, ResourceManager};
