//! Memory-reduction knobs for extreme-scale runs (§3.9 of the paper).
//!
//! To fit 501.51 billion agents into 92 TB the paper (1) disables
//! memory-costing optimizations, (2) switches to single-precision floats,
//! (3) shrinks the agent by changing its base class, and (4) compacts the
//! neighbor-search grid. [`CompactAgent`] is knob (2)+(3): an f32,
//! behavior-free agent with a one-byte class payload. The
//! [`capacity_model`] arithmetic turns measured bytes/agent into the
//! agents-per-memory extrapolation that EXPERIMENTS.md reports next to the
//! paper's numbers.

/// Minimal agent for extreme-scale capacity experiments: 21 bytes of
/// payload (padded to 24 by alignment), vs. the full [`Agent`]'s ~130+.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CompactAgent {
    pub position: [f32; 3],
    pub diameter: f32,
    /// Packed class id + flags.
    pub kind: u8,
    /// Model-specific small payload (e.g. cell type or SIR state).
    pub payload: u8,
}

impl CompactAgent {
    pub fn new(position: [f32; 3], diameter: f32, kind: u8, payload: u8) -> Self {
        CompactAgent { position, diameter, kind, payload }
    }

    /// Size of one agent in a dense array.
    pub const BYTES: usize = std::mem::size_of::<CompactAgent>();
}

/// Dense storage for compact agents: a plain SoA-free Vec is already
/// optimal at this payload size (the paper's reduced base class removes
/// exactly the indirections that would make AoS wasteful).
#[derive(Debug, Default)]
pub struct CompactStore {
    pub agents: Vec<CompactAgent>,
}

impl CompactStore {
    pub fn with_capacity(n: usize) -> Self {
        CompactStore { agents: Vec::with_capacity(n) }
    }

    pub fn len(&self) -> usize {
        self.agents.len()
    }

    pub fn is_empty(&self) -> bool {
        self.agents.is_empty()
    }

    pub fn push(&mut self, a: CompactAgent) {
        self.agents.push(a);
    }

    /// Exact live bytes of the store.
    pub fn bytes(&self) -> u64 {
        (self.agents.capacity() * CompactAgent::BYTES) as u64
    }
}

/// Capacity model used for the §3.9 extrapolation.
pub mod capacity_model {
    /// Agents that fit into `mem_bytes` at `bytes_per_agent` including an
    /// `overhead_factor` for engine structures (NSG, partition grid,
    /// buffers). The paper's 501.51e9 agents / 92 TB gives an effective
    /// ~183 bytes/agent end-to-end; our measured figures slot into the
    /// same formula.
    pub fn agents_for_memory(mem_bytes: u64, bytes_per_agent: f64, overhead_factor: f64) -> u64 {
        assert!(bytes_per_agent > 0.0 && overhead_factor >= 1.0);
        (mem_bytes as f64 / (bytes_per_agent * overhead_factor)) as u64
    }

    /// Effective bytes/agent of a measured run.
    pub fn effective_bytes_per_agent(mem_bytes: u64, agents: u64) -> f64 {
        assert!(agents > 0);
        mem_bytes as f64 / agents as f64
    }

    /// The paper's headline configuration for cross-checking the formula.
    pub const PAPER_EXTREME_AGENTS: u64 = 501_510_000_000;
    pub const PAPER_EXTREME_MEM_BYTES: u64 = 92 * 1024 * 1024 * 1024 * 1024;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compact_agent_is_small() {
        // The whole point of the knob: stay within 24 bytes.
        assert!(CompactAgent::BYTES <= 24, "CompactAgent grew to {}", CompactAgent::BYTES);
    }

    #[test]
    fn store_bytes_tracks_capacity() {
        let mut s = CompactStore::with_capacity(100);
        assert_eq!(s.bytes(), (100 * CompactAgent::BYTES) as u64);
        s.push(CompactAgent::new([0.0; 3], 1.0, 0, 0));
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn capacity_model_paper_cross_check() {
        use capacity_model::*;
        // Effective bytes/agent of the paper's extreme run ≈ 183.
        let bpa = effective_bytes_per_agent(PAPER_EXTREME_MEM_BYTES, PAPER_EXTREME_AGENTS);
        assert!((180.0..220.0).contains(&bpa), "paper bytes/agent = {bpa}");
        // Round trip: at that density the same memory holds the same count.
        let n = agents_for_memory(PAPER_EXTREME_MEM_BYTES, bpa, 1.0);
        let err = (n as f64 - PAPER_EXTREME_AGENTS as f64).abs() / PAPER_EXTREME_AGENTS as f64;
        assert!(err < 1e-6);
    }

    #[test]
    #[should_panic]
    fn capacity_model_rejects_zero_bytes() {
        capacity_model::agents_for_memory(1024, 0.0, 1.0);
    }
}
