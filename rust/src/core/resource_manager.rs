//! Per-rank agent storage (the paper's `ResourceManager`).
//!
//! Owned agents live in a slot vector indexed by the *local id*'s `index`
//! field — the "vector-based unordered map" of §2.5. Freed slots go to a
//! free list; reuse bumps the slot's `reuse` counter so stale `LocalId`s
//! can never alias a new agent. Aura (ghost) agents received from neighbor
//! ranks are stored separately and rebuilt every iteration. A
//! `GlobalId → slot` map supports [`AgentPointer`](super::ids::AgentPointer)
//! resolution and delta-encoding reference matching.

use super::agent::Agent;
use super::ids::{GlobalId, GlobalIdSource, LocalId};
use crate::util::Vec3;
use std::collections::HashMap;

/// Per-rank agent container.
#[derive(Debug)]
pub struct ResourceManager {
    /// Slot vector: `slots[local_id.index]`.
    slots: Vec<Option<Agent>>,
    /// Current reuse counter per slot (incremented on free).
    reuse: Vec<u32>,
    /// Free slot indices available for reuse.
    free: Vec<u32>,
    /// Number of live (owned) agents.
    live: usize,
    /// Aura agents (read-only copies of neighbor-rank agents).
    aura: Vec<Agent>,
    /// GlobalId -> owned slot index, for pointer resolution.
    global_map: HashMap<GlobalId, u32>,
    /// Issues global ids on demand.
    pub id_source: GlobalIdSource,
}

impl ResourceManager {
    pub fn new(rank: u32) -> Self {
        ResourceManager {
            slots: Vec::new(),
            reuse: Vec::new(),
            free: Vec::new(),
            live: 0,
            aura: Vec::new(),
            global_map: HashMap::new(),
            id_source: GlobalIdSource::new(rank),
        }
    }

    /// Number of live owned agents.
    #[inline]
    pub fn len(&self) -> usize {
        self.live
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Number of slots (capacity view; includes holes).
    pub fn slot_count(&self) -> usize {
        self.slots.len()
    }

    /// Add an agent, assigning its local id. Returns the id.
    pub fn add(&mut self, mut agent: Agent) -> LocalId {
        let index = match self.free.pop() {
            Some(i) => i,
            None => {
                self.slots.push(None);
                self.reuse.push(0);
                (self.slots.len() - 1) as u32
            }
        };
        let id = LocalId::new(index, self.reuse[index as usize]);
        agent.local_id = id;
        if agent.global_id.is_set() {
            self.global_map.insert(agent.global_id, index);
        }
        debug_assert!(self.slots[index as usize].is_none());
        self.slots[index as usize] = Some(agent);
        self.live += 1;
        id
    }

    /// Remove an agent by local id; returns it if the id was live.
    pub fn remove(&mut self, id: LocalId) -> Option<Agent> {
        let idx = id.index as usize;
        if idx >= self.slots.len() || self.reuse[idx] != id.reuse {
            return None;
        }
        let agent = self.slots[idx].take()?;
        // Bump reuse so stale ids can't resolve; recycle the slot.
        self.reuse[idx] = self.reuse[idx].wrapping_add(1);
        self.free.push(id.index);
        self.live -= 1;
        if agent.global_id.is_set() {
            self.global_map.remove(&agent.global_id);
        }
        Some(agent)
    }

    /// Borrow an agent by local id (None if stale or freed).
    #[inline]
    pub fn get(&self, id: LocalId) -> Option<&Agent> {
        let idx = id.index as usize;
        if idx >= self.slots.len() || self.reuse[idx] != id.reuse {
            return None;
        }
        self.slots[idx].as_ref()
    }

    /// Mutably borrow an agent by local id.
    #[inline]
    pub fn get_mut(&mut self, id: LocalId) -> Option<&mut Agent> {
        let idx = id.index as usize;
        if idx >= self.slots.len() || self.reuse[idx] != id.reuse {
            return None;
        }
        self.slots[idx].as_mut()
    }

    /// Resolve an agent by *global* id (owned agents only). This is the
    /// `AgentPointer` indirection: global id -> map -> reference.
    pub fn get_by_global(&self, gid: GlobalId) -> Option<&Agent> {
        let idx = *self.global_map.get(&gid)?;
        self.slots[idx as usize].as_ref()
    }

    /// Ensure the agent has a global id (generated on demand, §2.5) and
    /// return it.
    pub fn ensure_global_id(&mut self, id: LocalId) -> Option<GlobalId> {
        let idx = id.index as usize;
        if idx >= self.slots.len() || self.reuse[idx] != id.reuse {
            return None;
        }
        // Split borrow: take id_source before the slot borrow.
        let agent = self.slots[idx].as_mut()?;
        if !agent.global_id.is_set() {
            agent.global_id = self.id_source.next();
            self.global_map.insert(agent.global_id, id.index);
        }
        Some(agent.global_id)
    }

    /// Iterate live owned agents.
    pub fn iter(&self) -> impl Iterator<Item = &Agent> {
        self.slots.iter().filter_map(|s| s.as_ref())
    }

    /// Iterate live owned agents mutably.
    pub fn iter_mut(&mut self) -> impl Iterator<Item = &mut Agent> {
        self.slots.iter_mut().filter_map(|s| s.as_mut())
    }

    /// Live local ids (snapshot).
    pub fn ids(&self) -> Vec<LocalId> {
        self.iter().map(|a| a.local_id).collect()
    }

    // ----- aura ------------------------------------------------------------

    /// Replace the aura set (rebuilt each iteration, §2.2.1 Deallocation).
    pub fn set_aura(&mut self, agents: Vec<Agent>) {
        self.aura = agents;
    }

    pub fn clear_aura(&mut self) {
        self.aura.clear();
    }

    pub fn aura(&self) -> &[Agent] {
        &self.aura
    }

    pub fn aura_mut(&mut self) -> &mut Vec<Agent> {
        &mut self.aura
    }

    // ----- sorting ----------------------------------------------------------

    /// Agent sorting (§2.5): reorder agents so that agents close in space
    /// are close in memory (Morton order), improving cache hit rate. All
    /// agents move to fresh slots; local ids are reassigned; this is also
    /// the point where buffers of migrated-in agents are compacted away
    /// (the paper's deferred-deallocation story).
    pub fn sort_by_position(&mut self, origin: Vec3, cell: f64) {
        let mut agents: Vec<Agent> = self
            .slots
            .iter_mut()
            .filter_map(|s| s.take())
            .collect();
        agents.sort_by_key(|a| morton3(a.position - origin, cell));
        // Rebuild storage from scratch; reuse counters keep increasing per
        // slot so stale ids remain invalid.
        for r in self.reuse.iter_mut() {
            *r = r.wrapping_add(1);
        }
        self.slots.clear();
        self.slots.resize_with(agents.len(), || None);
        self.reuse.resize(agents.len().max(self.reuse.len()), 0);
        self.free.clear();
        self.global_map.clear();
        self.live = 0;
        let reuse_snapshot: Vec<u32> = self.reuse.clone();
        for (i, mut a) in agents.into_iter().enumerate() {
            let id = LocalId::new(i as u32, reuse_snapshot[i]);
            a.local_id = id;
            if a.global_id.is_set() {
                self.global_map.insert(a.global_id, i as u32);
            }
            self.slots[i] = Some(a);
            self.live += 1;
        }
    }

    /// Approximate live bytes of this container (for memory accounting).
    pub fn approx_bytes(&self) -> u64 {
        let slot_bytes = self.slots.capacity() * std::mem::size_of::<Option<Agent>>();
        let aux = self.reuse.capacity() * 4
            + self.free.capacity() * 4
            + self.global_map.len() * (std::mem::size_of::<GlobalId>() + 8);
        let behaviors: usize = self
            .iter()
            .map(|a| a.behaviors.capacity() * std::mem::size_of::<super::agent::Behavior>())
            .sum();
        let aura = self.aura.capacity() * std::mem::size_of::<Agent>();
        (slot_bytes + aux + behaviors + aura) as u64
    }
}

/// 3D Morton (Z-order) key of a position quantized to `cell`-sized bins.
/// 21 bits per axis (enough for 2M cells per axis).
pub fn morton3(p: Vec3, cell: f64) -> u64 {
    let q = |v: f64| -> u64 {
        let i = (v / cell).max(0.0) as u64;
        i.min((1 << 21) - 1)
    };
    interleave3(q(p.x)) | (interleave3(q(p.y)) << 1) | (interleave3(q(p.z)) << 2)
}

/// Spread the low 21 bits of `v` so consecutive bits are 3 apart.
fn interleave3(mut v: u64) -> u64 {
    v &= 0x1F_FFFF;
    v = (v | (v << 32)) & 0x1F00000000FFFF;
    v = (v | (v << 16)) & 0x1F0000FF0000FF;
    v = (v | (v << 8)) & 0x100F00F00F00F00F;
    v = (v | (v << 4)) & 0x10C30C30C30C30C3;
    v = (v | (v << 2)) & 0x1249249249249249;
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::agent::CellType;

    fn mk(pos: Vec3) -> Agent {
        Agent::cell(pos, 10.0, CellType::A)
    }

    #[test]
    fn add_get_remove() {
        let mut rm = ResourceManager::new(0);
        let id = rm.add(mk(Vec3::new(1.0, 2.0, 3.0)));
        assert_eq!(rm.len(), 1);
        assert_eq!(rm.get(id).unwrap().position, Vec3::new(1.0, 2.0, 3.0));
        let a = rm.remove(id).unwrap();
        assert_eq!(a.local_id, id);
        assert_eq!(rm.len(), 0);
        assert!(rm.get(id).is_none());
    }

    #[test]
    fn slot_reuse_bumps_counter() {
        let mut rm = ResourceManager::new(0);
        let id1 = rm.add(mk(Vec3::ZERO));
        rm.remove(id1).unwrap();
        let id2 = rm.add(mk(Vec3::ZERO));
        assert_eq!(id1.index, id2.index, "slot should be reused");
        assert_ne!(id1.reuse, id2.reuse, "reuse counter must differ");
        assert!(rm.get(id1).is_none(), "stale id must not resolve");
        assert!(rm.get(id2).is_some());
    }

    #[test]
    fn stale_id_mutation_refused() {
        let mut rm = ResourceManager::new(0);
        let id1 = rm.add(mk(Vec3::ZERO));
        rm.remove(id1);
        rm.add(mk(Vec3::ZERO));
        assert!(rm.get_mut(id1).is_none());
        assert!(rm.remove(id1).is_none());
    }

    #[test]
    fn global_id_on_demand() {
        let mut rm = ResourceManager::new(7);
        let id = rm.add(mk(Vec3::ZERO));
        assert!(!rm.get(id).unwrap().global_id.is_set());
        let gid = rm.ensure_global_id(id).unwrap();
        assert_eq!(gid.rank, 7);
        // Idempotent.
        assert_eq!(rm.ensure_global_id(id).unwrap(), gid);
        assert_eq!(rm.get_by_global(gid).unwrap().local_id, id);
    }

    #[test]
    fn iter_counts_live_only() {
        let mut rm = ResourceManager::new(0);
        let a = rm.add(mk(Vec3::ZERO));
        let _b = rm.add(mk(Vec3::ZERO));
        rm.remove(a);
        assert_eq!(rm.iter().count(), 1);
        assert_eq!(rm.len(), 1);
    }

    #[test]
    fn aura_replaced_wholesale() {
        let mut rm = ResourceManager::new(0);
        rm.set_aura(vec![mk(Vec3::ZERO), mk(Vec3::ZERO)]);
        assert_eq!(rm.aura().len(), 2);
        rm.set_aura(vec![mk(Vec3::ZERO)]);
        assert_eq!(rm.aura().len(), 1);
        rm.clear_aura();
        assert!(rm.aura().is_empty());
    }

    #[test]
    fn sort_preserves_agents_and_invalidates_old_ids() {
        let mut rm = ResourceManager::new(0);
        let mut ids = Vec::new();
        for i in 0..50 {
            ids.push(rm.add(mk(Vec3::new((50 - i) as f64, 0.0, 0.0))));
        }
        let gid = rm.ensure_global_id(ids[10]).unwrap();
        rm.sort_by_position(Vec3::ZERO, 1.0);
        assert_eq!(rm.len(), 50);
        // Old ids are stale now.
        assert!(rm.get(ids[0]).is_none());
        // Global id still resolves.
        assert!(rm.get_by_global(gid).is_some());
        // Positions are sorted along x (Morton of (x,0,0) is monotone in x).
        let xs: Vec<f64> = rm.iter().map(|a| a.position.x).collect();
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(xs, sorted);
    }

    #[test]
    fn morton_orders_locality() {
        // Near points should compare closer than far points along the curve.
        let a = morton3(Vec3::new(0.0, 0.0, 0.0), 1.0);
        let b = morton3(Vec3::new(1.0, 0.0, 0.0), 1.0);
        let far = morton3(Vec3::new(1000.0, 1000.0, 1000.0), 1.0);
        assert!(b > a);
        assert!(far > b);
        // Negative coordinates clamp to 0, never panic.
        let _ = morton3(Vec3::new(-5.0, -5.0, -5.0), 1.0);
    }

    #[test]
    fn approx_bytes_nonzero_when_populated() {
        let mut rm = ResourceManager::new(0);
        for _ in 0..10 {
            rm.add(mk(Vec3::ZERO));
        }
        assert!(rm.approx_bytes() > 0);
    }
}
