//! Per-rank agent storage (the paper's `ResourceManager`).
//!
//! Owned agents live in a slot vector indexed by the *local id*'s `index`
//! field — the "vector-based unordered map" of §2.5. Freed slots go to a
//! free list; reuse bumps the slot's `reuse` counter so stale `LocalId`s
//! can never alias a new agent. Aura (ghost) agents received from neighbor
//! ranks are stored separately and rebuilt every iteration. A
//! `GlobalId → slot` map supports [`AgentPointer`](super::ids::AgentPointer)
//! resolution and delta-encoding reference matching.
//!
//! # SoA hot-path mirror
//!
//! The per-iteration spatial hot path (mechanics gather, neighbor-attribute
//! reads) only needs three attributes per agent: position, diameter and
//! kind (the kind payload carries the adhesion coefficient). Chasing them
//! through `Vec<Option<Agent>>` costs an `Option` branch plus a 100+-byte
//! stride per access, so the manager keeps a structure-of-arrays mirror —
//! contiguous `pos`/`diam`/`kind` columns indexed by slot — and serves hot
//! reads from it ([`positions`](ResourceManager::positions),
//! [`col_position`](ResourceManager::col_position), …).
//!
//! The mirror is synchronized at every mutation point: `add`, the
//! [`set_position`](ResourceManager::set_position) fast path, and
//! `sort_by_position` write it directly, while [`get_mut`]
//! (ResourceManager::get_mut) returns an [`AgentRefMut`] guard that writes
//! the three columns back when dropped — models can keep mutating agents
//! through it without knowing the mirror exists. Columns of freed slots
//! hold stale values by design; they are only read through live `LocalId`s
//! (the NSG handle protocol guarantees liveness on the query path).

use super::agent::{Agent, AgentKind, Behavior, CellType};
use super::ids::{AgentPointer, GlobalId, GlobalIdSource, LocalId};
use crate::io::ta_io::ColumnSource;
use crate::util::Vec3;
use std::collections::HashMap;
use std::ops::{Deref, DerefMut};

/// Column filler for never-written slots (only live slots are ever read).
const KIND_FILL: AgentKind = AgentKind::Cell { cell_type: CellType::A, adhesion: 0.0 };

/// Mutable agent borrow that writes the hot-path SoA columns back on drop,
/// so arbitrary model mutations keep the mirror coherent.
pub struct AgentRefMut<'a> {
    agent: &'a mut Agent,
    pos: &'a mut Vec3,
    diam: &'a mut f64,
    kind: &'a mut AgentKind,
    gid: &'a mut GlobalId,
    nref: &'a mut AgentPointer,
    nbeh: &'a mut u32,
}

impl Deref for AgentRefMut<'_> {
    type Target = Agent;

    #[inline]
    fn deref(&self) -> &Agent {
        self.agent
    }
}

impl DerefMut for AgentRefMut<'_> {
    #[inline]
    fn deref_mut(&mut self) -> &mut Agent {
        self.agent
    }
}

impl Drop for AgentRefMut<'_> {
    #[inline]
    fn drop(&mut self) {
        *self.pos = self.agent.position;
        *self.diam = self.agent.diameter;
        *self.kind = self.agent.kind;
        *self.gid = self.agent.global_id;
        *self.nref = self.agent.neighbor_ref;
        *self.nbeh = self.agent.behaviors.len() as u32;
    }
}

/// Per-rank agent container.
///
/// # Example: add, read through the SoA mirror, sort
///
/// ```
/// use teraagent::core::agent::{Agent, CellType};
/// use teraagent::core::resource_manager::ResourceManager;
/// use teraagent::util::Vec3;
///
/// let mut rm = ResourceManager::new(0);
/// let id = rm.add(Agent::cell(Vec3::new(30.0, 2.0, 2.0), 10.0, CellType::A));
/// let _far = rm.add(Agent::cell(Vec3::new(90.0, 2.0, 2.0), 10.0, CellType::B));
///
/// // Hot reads come from the contiguous SoA columns…
/// assert_eq!(rm.col_position(id.index), Vec3::new(30.0, 2.0, 2.0));
/// // …which mutations through the write-back guard keep coherent.
/// rm.get_mut(id).unwrap().diameter = 12.5;
/// assert_eq!(rm.col_diameter(id.index), 12.5);
///
/// // The periodic Morton sort (§2.5) reassigns local ids: stale ids
/// // stop resolving, agents and global ids survive.
/// rm.sort_by_position(Vec3::ZERO, 10.0);
/// assert!(rm.get(id).is_none());
/// assert_eq!(rm.len(), 2);
/// ```
#[derive(Debug)]
pub struct ResourceManager {
    /// Slot vector: `slots[local_id.index]`.
    slots: Vec<Option<Agent>>,
    /// Current reuse counter per slot (incremented on free).
    reuse: Vec<u32>,
    /// Free slot indices available for reuse.
    free: Vec<u32>,
    /// Number of live (owned) agents.
    live: usize,
    /// SoA mirror of the hot attributes, indexed by slot.
    pos_col: Vec<Vec3>,
    diam_col: Vec<f64>,
    kind_col: Vec<AgentKind>,
    /// Exchange-path mirror columns: global id, agent reference and
    /// behavior count — everything the columnar TA IO writer needs to
    /// assemble an `AgentBlock` without reading the `Agent` struct.
    gid_col: Vec<GlobalId>,
    ref_col: Vec<AgentPointer>,
    nbeh_col: Vec<u32>,
    /// Aura agents (read-only copies of neighbor-rank agents).
    aura: Vec<Agent>,
    /// GlobalId -> owned slot index, for pointer resolution.
    global_map: HashMap<GlobalId, u32>,
    /// Issues global ids on demand.
    pub id_source: GlobalIdSource,
}

impl ResourceManager {
    pub fn new(rank: u32) -> Self {
        ResourceManager {
            slots: Vec::new(),
            reuse: Vec::new(),
            free: Vec::new(),
            live: 0,
            pos_col: Vec::new(),
            diam_col: Vec::new(),
            kind_col: Vec::new(),
            gid_col: Vec::new(),
            ref_col: Vec::new(),
            nbeh_col: Vec::new(),
            aura: Vec::new(),
            global_map: HashMap::new(),
            id_source: GlobalIdSource::new(rank),
        }
    }

    /// Number of live owned agents.
    #[inline]
    pub fn len(&self) -> usize {
        self.live
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Number of slots (capacity view; includes holes).
    pub fn slot_count(&self) -> usize {
        self.slots.len()
    }

    /// Add an agent, assigning its local id. Returns the id.
    pub fn add(&mut self, mut agent: Agent) -> LocalId {
        let index = match self.free.pop() {
            Some(i) => i,
            None => {
                self.slots.push(None);
                self.reuse.push(0);
                self.pos_col.push(Vec3::ZERO);
                self.diam_col.push(0.0);
                self.kind_col.push(KIND_FILL);
                self.gid_col.push(GlobalId::UNSET);
                self.ref_col.push(AgentPointer::NULL);
                self.nbeh_col.push(0);
                (self.slots.len() - 1) as u32
            }
        };
        let id = LocalId::new(index, self.reuse[index as usize]);
        agent.local_id = id;
        if agent.global_id.is_set() {
            self.global_map.insert(agent.global_id, index);
        }
        debug_assert!(self.slots[index as usize].is_none());
        self.pos_col[index as usize] = agent.position;
        self.diam_col[index as usize] = agent.diameter;
        self.kind_col[index as usize] = agent.kind;
        self.gid_col[index as usize] = agent.global_id;
        self.ref_col[index as usize] = agent.neighbor_ref;
        self.nbeh_col[index as usize] = agent.behaviors.len() as u32;
        self.slots[index as usize] = Some(agent);
        self.live += 1;
        id
    }

    /// Remove an agent by local id; returns it if the id was live.
    pub fn remove(&mut self, id: LocalId) -> Option<Agent> {
        let idx = id.index as usize;
        if idx >= self.slots.len() || self.reuse[idx] != id.reuse {
            return None;
        }
        let agent = self.slots[idx].take()?;
        // Bump reuse so stale ids can't resolve; recycle the slot. (The
        // SoA columns keep their now-stale values; only live ids read
        // them.)
        self.reuse[idx] = self.reuse[idx].wrapping_add(1);
        self.free.push(id.index);
        self.live -= 1;
        if agent.global_id.is_set() {
            self.global_map.remove(&agent.global_id);
        }
        Some(agent)
    }

    /// Borrow an agent by local id (None if stale or freed).
    #[inline]
    pub fn get(&self, id: LocalId) -> Option<&Agent> {
        let idx = id.index as usize;
        if idx >= self.slots.len() || self.reuse[idx] != id.reuse {
            return None;
        }
        self.slots[idx].as_ref()
    }

    /// Mutably borrow an agent by local id. The returned guard derefs to
    /// `Agent` and flushes the hot-path columns when dropped.
    #[inline]
    pub fn get_mut(&mut self, id: LocalId) -> Option<AgentRefMut<'_>> {
        let idx = id.index as usize;
        if idx >= self.slots.len() || self.reuse[idx] != id.reuse {
            return None;
        }
        let agent = self.slots[idx].as_mut()?;
        Some(AgentRefMut {
            agent,
            pos: &mut self.pos_col[idx],
            diam: &mut self.diam_col[idx],
            kind: &mut self.kind_col[idx],
            gid: &mut self.gid_col[idx],
            nref: &mut self.ref_col[idx],
            nbeh: &mut self.nbeh_col[idx],
        })
    }

    /// O(1) position write-through: updates the agent and the `pos`
    /// column without materializing a guard (the mechanics apply loop and
    /// `World::move_agent` fast path). Returns `false` for stale ids.
    #[inline]
    pub fn set_position(&mut self, id: LocalId, pos: Vec3) -> bool {
        let idx = id.index as usize;
        if idx >= self.slots.len() || self.reuse[idx] != id.reuse {
            return false;
        }
        match self.slots[idx].as_mut() {
            Some(a) => {
                a.position = pos;
                self.pos_col[idx] = pos;
                true
            }
            None => false,
        }
    }

    // ----- SoA mirror reads ------------------------------------------------

    /// Contiguous position column (indexed by slot; stale for holes).
    #[inline]
    pub fn positions(&self) -> &[Vec3] {
        &self.pos_col
    }

    /// Contiguous diameter column (indexed by slot; stale for holes).
    #[inline]
    pub fn diameters(&self) -> &[f64] {
        &self.diam_col
    }

    /// Contiguous kind column (indexed by slot; stale for holes). The
    /// kind payload carries the per-class adhesion coefficient.
    #[inline]
    pub fn kinds(&self) -> &[AgentKind] {
        &self.kind_col
    }

    /// Position of the agent in slot `index` (must be live).
    #[inline]
    pub fn col_position(&self, index: u32) -> Vec3 {
        self.pos_col[index as usize]
    }

    /// Diameter of the agent in slot `index` (must be live).
    #[inline]
    pub fn col_diameter(&self, index: u32) -> f64 {
        self.diam_col[index as usize]
    }

    /// Kind of the agent in slot `index` (must be live).
    #[inline]
    pub fn col_kind(&self, index: u32) -> AgentKind {
        self.kind_col[index as usize]
    }

    /// Column view for the TA IO SoA-direct encoder. Slots of freed
    /// agents hold stale values; callers index only through live ids.
    #[inline]
    pub fn columns(&self) -> ColumnSource<'_> {
        ColumnSource {
            pos: &self.pos_col,
            diam: &self.diam_col,
            kind: &self.kind_col,
            gid: &self.gid_col,
            nref: &self.ref_col,
            nbeh: &self.nbeh_col,
        }
    }

    /// Behavior slice of the agent in slot `index` (empty for holes) —
    /// the variable-length tail the columnar writer resolves per agent.
    #[inline]
    pub fn behaviors_of_slot(&self, index: u32) -> &[Behavior] {
        self.slots[index as usize].as_ref().map_or(&[], |a| &a.behaviors[..])
    }

    // -----------------------------------------------------------------------

    /// Resolve an agent by *global* id (owned agents only). This is the
    /// `AgentPointer` indirection: global id -> map -> reference.
    pub fn get_by_global(&self, gid: GlobalId) -> Option<&Agent> {
        let idx = *self.global_map.get(&gid)?;
        self.slots[idx as usize].as_ref()
    }

    /// Ensure the agent has a global id (generated on demand, §2.5) and
    /// return it.
    pub fn ensure_global_id(&mut self, id: LocalId) -> Option<GlobalId> {
        let idx = id.index as usize;
        if idx >= self.slots.len() || self.reuse[idx] != id.reuse {
            return None;
        }
        // Split borrow: take id_source before the slot borrow.
        let agent = self.slots[idx].as_mut()?;
        if !agent.global_id.is_set() {
            agent.global_id = self.id_source.next();
            self.global_map.insert(agent.global_id, id.index);
            self.gid_col[idx] = agent.global_id;
        }
        Some(agent.global_id)
    }

    /// Iterate live owned agents.
    pub fn iter(&self) -> impl Iterator<Item = &Agent> {
        self.slots.iter().filter_map(|s| s.as_ref())
    }

    /// Live local ids (snapshot, slot order).
    pub fn ids(&self) -> Vec<LocalId> {
        self.iter().map(|a| a.local_id).collect()
    }

    /// Append live local ids into `out` (slot order) — the
    /// allocation-free variant for per-iteration scratch reuse.
    pub fn collect_ids(&self, out: &mut Vec<LocalId>) {
        out.reserve(self.live); // no-op once the buffer reached steady state
        for a in self.iter() {
            out.push(a.local_id);
        }
    }

    // ----- aura ------------------------------------------------------------

    /// Replace the aura set (rebuilt each iteration, §2.2.1 Deallocation).
    pub fn set_aura(&mut self, agents: Vec<Agent>) {
        self.aura = agents;
    }

    pub fn clear_aura(&mut self) {
        self.aura.clear();
    }

    pub fn aura(&self) -> &[Agent] {
        &self.aura
    }

    pub fn aura_mut(&mut self) -> &mut Vec<Agent> {
        &mut self.aura
    }

    // ----- sorting ----------------------------------------------------------

    /// Agent sorting (§2.5): reorder agents so that agents close in space
    /// are close in memory (Morton order), improving cache hit rate. All
    /// agents move to fresh slots; local ids are reassigned; this is also
    /// the point where buffers of migrated-in agents are compacted away
    /// (the paper's deferred-deallocation story). The SoA mirror is
    /// rebuilt in the same pass, so after sorting the hot columns stream
    /// in Morton order too.
    pub fn sort_by_position(&mut self, origin: Vec3, cell: f64) {
        self.resort(|a| morton3(a.position - origin, cell));
    }

    /// [`sort_by_position`](Self::sort_by_position) with the quantized
    /// coordinates **clamped to `dims`** — the exact cell mapping of a
    /// `NeighborSearchGrid` with the same origin, cell size and logical
    /// dims (see [`morton3_in_grid`]). After this sort, slot order is
    /// non-decreasing in the grid's Morton cell index even for positions
    /// at or beyond the far domain edge, which is the precondition for
    /// the grid's parallel wholesale rebuild
    /// (`NeighborSearchGrid::rebuild_owned`).
    pub fn sort_by_grid(&mut self, origin: Vec3, cell: f64, dims: [usize; 3]) {
        self.resort(|a| morton3_in_grid(a.position - origin, cell, dims));
    }

    /// Shared resort body: drain, order by `key`, rebuild storage and the
    /// SoA mirror from scratch.
    fn resort(&mut self, key: impl Fn(&Agent) -> u64) {
        let mut agents: Vec<Agent> = self
            .slots
            .iter_mut()
            .filter_map(|s| s.take())
            .collect();
        agents.sort_by_key(|a| key(a));
        // Rebuild storage from scratch; reuse counters keep increasing per
        // slot so stale ids remain invalid.
        for r in self.reuse.iter_mut() {
            *r = r.wrapping_add(1);
        }
        self.slots.clear();
        self.slots.resize_with(agents.len(), || None);
        self.reuse.resize(agents.len().max(self.reuse.len()), 0);
        self.pos_col.clear();
        self.pos_col.resize(agents.len(), Vec3::ZERO);
        self.diam_col.clear();
        self.diam_col.resize(agents.len(), 0.0);
        self.kind_col.clear();
        self.kind_col.resize(agents.len(), KIND_FILL);
        self.gid_col.clear();
        self.gid_col.resize(agents.len(), GlobalId::UNSET);
        self.ref_col.clear();
        self.ref_col.resize(agents.len(), AgentPointer::NULL);
        self.nbeh_col.clear();
        self.nbeh_col.resize(agents.len(), 0);
        self.free.clear();
        self.global_map.clear();
        self.live = 0;
        let reuse_snapshot: Vec<u32> = self.reuse.clone();
        for (i, mut a) in agents.into_iter().enumerate() {
            let id = LocalId::new(i as u32, reuse_snapshot[i]);
            a.local_id = id;
            if a.global_id.is_set() {
                self.global_map.insert(a.global_id, i as u32);
            }
            self.pos_col[i] = a.position;
            self.diam_col[i] = a.diameter;
            self.kind_col[i] = a.kind;
            self.gid_col[i] = a.global_id;
            self.ref_col[i] = a.neighbor_ref;
            self.nbeh_col[i] = a.behaviors.len() as u32;
            self.slots[i] = Some(a);
            self.live += 1;
        }
    }

    /// Approximate live bytes of this container (for memory accounting).
    pub fn approx_bytes(&self) -> u64 {
        let slot_bytes = self.slots.capacity() * std::mem::size_of::<Option<Agent>>();
        let aux = self.reuse.capacity() * 4
            + self.free.capacity() * 4
            + self.pos_col.capacity() * std::mem::size_of::<Vec3>()
            + self.diam_col.capacity() * 8
            + self.kind_col.capacity() * std::mem::size_of::<AgentKind>()
            + self.gid_col.capacity() * std::mem::size_of::<GlobalId>()
            + self.ref_col.capacity() * std::mem::size_of::<AgentPointer>()
            + self.nbeh_col.capacity() * 4
            + self.global_map.len() * (std::mem::size_of::<GlobalId>() + 8);
        let behaviors: usize = self
            .iter()
            .map(|a| a.behaviors.capacity() * std::mem::size_of::<super::agent::Behavior>())
            .sum();
        let aura = self.aura.capacity() * std::mem::size_of::<Agent>();
        (slot_bytes + aux + behaviors + aura) as u64
    }
}

/// 3D Morton (Z-order) key of a position quantized to `cell`-sized bins.
/// 21 bits per axis (enough for 2M cells per axis).
pub fn morton3(p: Vec3, cell: f64) -> u64 {
    let q = |v: f64| -> u64 {
        let i = (v / cell).max(0.0) as u64;
        i.min((1 << 21) - 1)
    };
    interleave3(q(p.x)) | (interleave3(q(p.y)) << 1) | (interleave3(q(p.z)) << 2)
}

/// Per-axis grid bin of a coordinate relative to the grid origin: the
/// **single** quantizer shared by the agent sort key
/// ([`morton3_in_grid`]) and the NSG's cell map
/// (`space::nsg::CellMap::coords_of`). The parallel NSG rebuild's fast
/// path requires those two to agree bit-for-bit — slot order must be
/// non-decreasing in cell index after `sort_by_grid` — so the formula
/// lives in exactly one place. Do not fork it.
#[inline]
pub fn grid_axis_bin(v: f64, cell: f64, d: usize) -> usize {
    if v <= 0.0 {
        0
    } else {
        ((v / cell) as usize).min(d - 1)
    }
}

/// [`morton3`] with each axis quantized by [`grid_axis_bin`] — the exact
/// cell coordinate of a `NeighborSearchGrid` with the same origin, cell
/// size and logical dims — so ordering by this key orders agents by
/// their grid cell's Morton index. `p` is the position *relative to the
/// grid origin* (`position - bounds.min`), as in [`morton3`]. Axes are
/// additionally saturated at the 21-bit interleave width (the NSG caps
/// its dims there too, so the saturation never diverges from the grid).
pub fn morton3_in_grid(p: Vec3, cell: f64, dims: [usize; 3]) -> u64 {
    let q = |v: f64, d: usize| -> u64 {
        (grid_axis_bin(v, cell, d) as u64).min((1 << 21) - 1)
    };
    interleave3(q(p.x, dims[0]))
        | (interleave3(q(p.y, dims[1])) << 1)
        | (interleave3(q(p.z, dims[2])) << 2)
}

/// Spread the low 21 bits of `v` so consecutive bits are 3 apart.
fn interleave3(mut v: u64) -> u64 {
    v &= 0x1F_FFFF;
    v = (v | (v << 32)) & 0x1F00000000FFFF;
    v = (v | (v << 16)) & 0x1F0000FF0000FF;
    v = (v | (v << 8)) & 0x100F00F00F00F00F;
    v = (v | (v << 4)) & 0x10C30C30C30C30C3;
    v = (v | (v << 2)) & 0x1249249249249249;
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::agent::CellType;

    fn mk(pos: Vec3) -> Agent {
        Agent::cell(pos, 10.0, CellType::A)
    }

    #[test]
    fn add_get_remove() {
        let mut rm = ResourceManager::new(0);
        let id = rm.add(mk(Vec3::new(1.0, 2.0, 3.0)));
        assert_eq!(rm.len(), 1);
        assert_eq!(rm.get(id).unwrap().position, Vec3::new(1.0, 2.0, 3.0));
        let a = rm.remove(id).unwrap();
        assert_eq!(a.local_id, id);
        assert_eq!(rm.len(), 0);
        assert!(rm.get(id).is_none());
    }

    #[test]
    fn slot_reuse_bumps_counter() {
        let mut rm = ResourceManager::new(0);
        let id1 = rm.add(mk(Vec3::ZERO));
        rm.remove(id1).unwrap();
        let id2 = rm.add(mk(Vec3::ZERO));
        assert_eq!(id1.index, id2.index, "slot should be reused");
        assert_ne!(id1.reuse, id2.reuse, "reuse counter must differ");
        assert!(rm.get(id1).is_none(), "stale id must not resolve");
        assert!(rm.get(id2).is_some());
    }

    #[test]
    fn stale_id_mutation_refused() {
        let mut rm = ResourceManager::new(0);
        let id1 = rm.add(mk(Vec3::ZERO));
        rm.remove(id1);
        rm.add(mk(Vec3::ZERO));
        assert!(rm.get_mut(id1).is_none());
        assert!(rm.remove(id1).is_none());
        assert!(!rm.set_position(id1, Vec3::splat(1.0)));
    }

    #[test]
    fn global_id_on_demand() {
        let mut rm = ResourceManager::new(7);
        let id = rm.add(mk(Vec3::ZERO));
        assert!(!rm.get(id).unwrap().global_id.is_set());
        let gid = rm.ensure_global_id(id).unwrap();
        assert_eq!(gid.rank, 7);
        // Idempotent.
        assert_eq!(rm.ensure_global_id(id).unwrap(), gid);
        assert_eq!(rm.get_by_global(gid).unwrap().local_id, id);
    }

    #[test]
    fn iter_counts_live_only() {
        let mut rm = ResourceManager::new(0);
        let a = rm.add(mk(Vec3::ZERO));
        let _b = rm.add(mk(Vec3::ZERO));
        rm.remove(a);
        assert_eq!(rm.iter().count(), 1);
        assert_eq!(rm.len(), 1);
    }

    #[test]
    fn aura_replaced_wholesale() {
        let mut rm = ResourceManager::new(0);
        rm.set_aura(vec![mk(Vec3::ZERO), mk(Vec3::ZERO)]);
        assert_eq!(rm.aura().len(), 2);
        rm.set_aura(vec![mk(Vec3::ZERO)]);
        assert_eq!(rm.aura().len(), 1);
        rm.clear_aura();
        assert!(rm.aura().is_empty());
    }

    #[test]
    fn sort_preserves_agents_and_invalidates_old_ids() {
        let mut rm = ResourceManager::new(0);
        let mut ids = Vec::new();
        for i in 0..50 {
            ids.push(rm.add(mk(Vec3::new((50 - i) as f64, 0.0, 0.0))));
        }
        let gid = rm.ensure_global_id(ids[10]).unwrap();
        rm.sort_by_position(Vec3::ZERO, 1.0);
        assert_eq!(rm.len(), 50);
        // Old ids are stale now.
        assert!(rm.get(ids[0]).is_none());
        // Global id still resolves.
        assert!(rm.get_by_global(gid).is_some());
        // Positions are sorted along x (Morton of (x,0,0) is monotone in x).
        let xs: Vec<f64> = rm.iter().map(|a| a.position.x).collect();
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(xs, sorted);
        // The SoA mirror was rebuilt in the same order.
        for a in rm.iter() {
            assert_eq!(rm.col_position(a.local_id.index), a.position);
            assert_eq!(rm.col_diameter(a.local_id.index), a.diameter);
        }
    }

    #[test]
    fn morton_orders_locality() {
        // Near points should compare closer than far points along the curve.
        let a = morton3(Vec3::new(0.0, 0.0, 0.0), 1.0);
        let b = morton3(Vec3::new(1.0, 0.0, 0.0), 1.0);
        let far = morton3(Vec3::new(1000.0, 1000.0, 1000.0), 1.0);
        assert!(b > a);
        assert!(far > b);
        // Negative coordinates clamp to 0, never panic.
        let _ = morton3(Vec3::new(-5.0, -5.0, -5.0), 1.0);
    }

    #[test]
    fn approx_bytes_nonzero_when_populated() {
        let mut rm = ResourceManager::new(0);
        for _ in 0..10 {
            rm.add(mk(Vec3::ZERO));
        }
        assert!(rm.approx_bytes() > 0);
    }

    // ----- SoA mirror coherence --------------------------------------------

    #[test]
    fn soa_mirror_tracks_add_and_set_position() {
        let mut rm = ResourceManager::new(0);
        let id = rm.add(mk(Vec3::new(1.0, 2.0, 3.0)));
        assert_eq!(rm.col_position(id.index), Vec3::new(1.0, 2.0, 3.0));
        assert_eq!(rm.col_diameter(id.index), 10.0);
        assert!(rm.set_position(id, Vec3::new(4.0, 5.0, 6.0)));
        assert_eq!(rm.col_position(id.index), Vec3::new(4.0, 5.0, 6.0));
        assert_eq!(rm.get(id).unwrap().position, Vec3::new(4.0, 5.0, 6.0));
        assert_eq!(rm.positions().len(), rm.slot_count());
        assert_eq!(rm.diameters().len(), rm.slot_count());
        assert_eq!(rm.kinds().len(), rm.slot_count());
    }

    #[test]
    fn soa_mirror_flushes_on_guard_drop() {
        let mut rm = ResourceManager::new(0);
        let id = rm.add(mk(Vec3::ZERO));
        {
            let mut a = rm.get_mut(id).unwrap();
            a.position = Vec3::new(7.0, 8.0, 9.0);
            a.diameter = 3.5;
            a.kind = AgentKind::Cell { cell_type: CellType::B, adhesion: 0.9 };
        } // guard drop flushes the columns
        assert_eq!(rm.col_position(id.index), Vec3::new(7.0, 8.0, 9.0));
        assert_eq!(rm.col_diameter(id.index), 3.5);
        assert!(matches!(
            rm.col_kind(id.index),
            AgentKind::Cell { cell_type: CellType::B, .. }
        ));
        // A second mutation through a fresh guard also flushes.
        {
            let mut a = rm.get_mut(id).unwrap();
            a.diameter = 4.25;
        }
        assert_eq!(rm.col_diameter(id.index), 4.25);
    }

    #[test]
    fn soa_mirror_after_slot_recycling() {
        let mut rm = ResourceManager::new(0);
        let a = rm.add(mk(Vec3::splat(1.0)));
        rm.remove(a).unwrap();
        let b = rm.add(mk(Vec3::splat(2.0)));
        assert_eq!(a.index, b.index);
        assert_eq!(rm.col_position(b.index), Vec3::splat(2.0));
    }

    #[test]
    fn exchange_columns_track_mutations() {
        let mut rm = ResourceManager::new(4);
        let id = rm.add(mk(Vec3::ZERO));
        let cols = rm.columns();
        assert_eq!(cols.gid[id.index as usize], crate::core::ids::GlobalId::UNSET);
        assert_eq!(cols.nbeh[id.index as usize], 0);
        // ensure_global_id writes through to the gid column.
        let gid = rm.ensure_global_id(id).unwrap();
        assert_eq!(rm.columns().gid[id.index as usize], gid);
        // Guard drop flushes behaviors count and neighbor ref.
        let target = crate::core::ids::GlobalId::new(1, 9);
        {
            let mut a = rm.get_mut(id).unwrap();
            a.behaviors.push(crate::core::agent::Behavior::Divide);
            a.neighbor_ref = AgentPointer::to(target);
        }
        assert_eq!(rm.columns().nbeh[id.index as usize], 1);
        assert_eq!(rm.columns().nref[id.index as usize].target, target);
        assert_eq!(rm.behaviors_of_slot(id.index).len(), 1);
        // Sorting rebuilds the exchange columns coherently.
        rm.sort_by_position(Vec3::ZERO, 1.0);
        let a = rm.iter().next().unwrap();
        let idx = a.local_id.index as usize;
        assert_eq!(rm.columns().gid[idx], gid);
        assert_eq!(rm.columns().nbeh[idx], 1);
        assert_eq!(rm.columns().nref[idx].target, target);
    }

    #[test]
    fn collect_ids_reuses_buffer() {
        let mut rm = ResourceManager::new(0);
        for _ in 0..5 {
            rm.add(mk(Vec3::ZERO));
        }
        let mut buf = Vec::new();
        rm.collect_ids(&mut buf);
        assert_eq!(buf.len(), 5);
        let cap = buf.capacity();
        buf.clear();
        rm.collect_ids(&mut buf);
        assert_eq!(buf.len(), 5);
        assert_eq!(buf.capacity(), cap, "steady-state collect must not realloc");
        assert_eq!(buf, rm.ids());
    }
}
