//! Per-rank agent storage (the paper's `ResourceManager`).
//!
//! Owned agents live in a slot vector indexed by the *local id*'s `index`
//! field — the "vector-based unordered map" of §2.5. Freed slots go to a
//! free list; reuse bumps the slot's `reuse` counter so stale `LocalId`s
//! can never alias a new agent. Aura (ghost) agents received from neighbor
//! ranks are stored separately and rebuilt every iteration. A
//! `GlobalId → slot` map supports [`AgentPointer`](super::ids::AgentPointer)
//! resolution and delta-encoding reference matching.
//!
//! # SoA hot-path mirror
//!
//! The per-iteration spatial hot path (mechanics gather, neighbor-attribute
//! reads) only needs three attributes per agent: position, diameter and
//! kind (the kind payload carries the adhesion coefficient). Chasing them
//! through `Vec<Option<Agent>>` costs an `Option` branch plus a 100+-byte
//! stride per access, so the manager keeps a structure-of-arrays mirror —
//! contiguous `pos`/`diam`/`kind` columns indexed by slot — and serves hot
//! reads from it ([`positions`](ResourceManager::positions),
//! [`col_position`](ResourceManager::col_position), …).
//!
//! The mirror is synchronized at every mutation point: `add`, the
//! [`set_position`](ResourceManager::set_position) fast path, and
//! `sort_by_position` write it directly, while [`get_mut`]
//! (ResourceManager::get_mut) returns an [`AgentRefMut`] guard that writes
//! the three columns back when dropped — models can keep mutating agents
//! through it without knowing the mirror exists. Columns of freed slots
//! hold stale values by design; they are only read through live `LocalId`s
//! (the NSG handle protocol guarantees liveness on the query path).
//!
//! # Behavior arena
//!
//! Agents do **not** own their behaviors: every behavior of every owned
//! agent lives in one flat [`BehaviorArena`] pool, addressed per slot by
//! the `beh_off`/`beh_len` columns (`beh_len` doubles as the columnar
//! writer's `nbeh` column). The arena is the *whole-agent* completion of
//! the SoA story — the variable-length behavior tail becomes columnar too,
//! so the TA IO writer, the codec and the behavior-execution sweep stream
//! behaviors from contiguous memory instead of chasing per-agent `Vec`s.
//! Churn between sorts (attach/detach/remove) is served by a
//! first-fit free-extent list with coalescing; the periodic Morton sort
//! ([`sort_by_grid`](ResourceManager::sort_by_grid)) re-packs the pool in
//! slot order in the same pass that compacts the slot vector, restoring
//! perfect traversal order. See ARCHITECTURE.md §"Behavior arena".

use super::agent::{Agent, AgentKind, Behavior, CellType};
use super::ids::{AgentPointer, GlobalId, GlobalIdSource, LocalId};
use crate::engine::pool::ThreadPool;
use crate::io::ta_io::ColumnSource;
use crate::util::Vec3;
use std::collections::HashMap;
use std::ops::{Deref, DerefMut};

/// Column filler for never-written slots (only live slots are ever read).
const KIND_FILL: AgentKind = AgentKind::Cell { cell_type: CellType::A, adhesion: 0.0 };

/// Flat pool of every behavior of every owned agent, in per-agent extents.
///
/// Invariant: the pool is exactly partitioned into live extents (addressed
/// by the owning `ResourceManager`'s `beh_off`/`beh_len` columns) and the
/// extents on the `free` list — pairwise disjoint, jointly covering
/// `0..pool.len()`. The free list is kept sorted by offset and coalesced,
/// and a freed extent that ends the pool is truncated away instead of
/// parked, so steady-state churn cannot grow the pool's span beyond its
/// high-water live size + fragmentation.
#[derive(Debug, Default)]
pub struct BehaviorArena {
    pool: Vec<Behavior>,
    /// Free extents `(offset, len)`, sorted by offset, coalesced.
    free: Vec<(u32, u32)>,
    /// Number of live (reachable) behaviors in the pool.
    live: u32,
    /// Spare buffer double-buffering the compaction pass (allocation-free
    /// in steady state).
    spare: Vec<Behavior>,
}

impl BehaviorArena {
    pub fn new() -> BehaviorArena {
        BehaviorArena::default()
    }

    /// The whole pool (live and free extents interleaved; index only
    /// through live `(off, len)` extents).
    #[inline]
    pub fn pool(&self) -> &[Behavior] {
        &self.pool
    }

    /// Length of the pool span (live + free slots).
    #[inline]
    pub fn pool_len(&self) -> usize {
        self.pool.len()
    }

    /// Number of live behaviors.
    #[inline]
    pub fn live_len(&self) -> u32 {
        self.live
    }

    /// Number of free extents (fragmentation view).
    #[inline]
    pub fn free_extents(&self) -> usize {
        self.free.len()
    }

    /// Borrow a live extent.
    #[inline]
    pub fn slice(&self, off: u32, len: u32) -> &[Behavior] {
        &self.pool[off as usize..(off + len) as usize]
    }

    /// Mutably borrow a live extent.
    #[inline]
    pub fn slice_mut(&mut self, off: u32, len: u32) -> &mut [Behavior] {
        &mut self.pool[off as usize..(off + len) as usize]
    }

    /// Allocate an extent holding `bs` (first-fit from the free list, else
    /// appended at the pool end). Returns the extent offset.
    pub fn alloc(&mut self, bs: &[Behavior]) -> u32 {
        let len = bs.len() as u32;
        if len == 0 {
            return 0;
        }
        let off = self.reserve(len);
        self.pool[off as usize..(off + len) as usize].copy_from_slice(bs);
        off
    }

    /// [`alloc`](Self::alloc) filling the extent from an iterator (used by
    /// wire decode to move behavior blocks straight into the pool).
    pub fn alloc_from(&mut self, it: impl ExactSizeIterator<Item = Behavior>) -> (u32, u32) {
        let len = it.len() as u32;
        if len == 0 {
            return (0, 0);
        }
        let off = self.reserve(len);
        for (j, b) in it.enumerate() {
            self.pool[off as usize + j] = b;
        }
        (off, len)
    }

    /// Reserve a `len`-slot extent (contents unspecified until written).
    fn reserve(&mut self, len: u32) -> u32 {
        debug_assert!(len > 0);
        self.live += len;
        if let Some(k) = self.free.iter().position(|&(_, l)| l >= len) {
            let (fo, fl) = self.free[k];
            if fl == len {
                self.free.remove(k);
            } else {
                self.free[k] = (fo + len, fl - len);
            }
            fo
        } else {
            let fo = self.pool.len() as u32;
            // `Divide` carries no payload and is the cheapest filler.
            self.pool.resize(self.pool.len() + len as usize, Behavior::Divide);
            fo
        }
    }

    /// Return a live extent to the free list (coalescing with adjacent
    /// free extents; an extent ending the pool is truncated away).
    pub fn free_extent(&mut self, off: u32, len: u32) {
        if len == 0 {
            return;
        }
        debug_assert!(self.live >= len);
        self.live -= len;
        let mut off = off;
        let mut len = len;
        let mut k = self.free.partition_point(|&(o, _)| o < off);
        if k > 0 {
            let (po, pl) = self.free[k - 1];
            debug_assert!(po + pl <= off, "freeing an extent overlapping a free one");
            if po + pl == off {
                off = po;
                len += pl;
                self.free.remove(k - 1);
                k -= 1;
            }
        }
        if k < self.free.len() {
            let (no, nl) = self.free[k];
            debug_assert!(off + len <= no, "freeing an extent overlapping a free one");
            if off + len == no {
                len += nl;
                self.free.remove(k);
            }
        }
        if (off + len) as usize == self.pool.len() {
            self.pool.truncate(off as usize);
        } else {
            self.free.insert(k, (off, len));
        }
    }

    /// Reallocate extent `(off, len)` to `(off', len + 1)` with `b`
    /// appended; returns the new offset. Extends in place when the extent
    /// ends the pool.
    pub fn grow_extent(&mut self, off: u32, len: u32, b: Behavior) -> u32 {
        if len > 0 && (off + len) as usize == self.pool.len() {
            self.pool.push(b);
            self.live += 1;
            return off;
        }
        let need = len + 1;
        let noff = if let Some(k) = self.free.iter().position(|&(_, l)| l >= need) {
            let (fo, fl) = self.free[k];
            if fl == need {
                self.free.remove(k);
            } else {
                self.free[k] = (fo + need, fl - need);
            }
            for j in 0..len {
                self.pool[(fo + j) as usize] = self.pool[(off + j) as usize];
            }
            self.pool[(fo + len) as usize] = b;
            fo
        } else {
            let fo = self.pool.len() as u32;
            for j in 0..len {
                let v = self.pool[(off + j) as usize];
                self.pool.push(v);
            }
            self.pool.push(b);
            fo
        };
        // The new extent is live (`need` slots); freeing the old one below
        // subtracts its `len`, netting the +1.
        self.live += need;
        self.free_extent(off, len);
        noff
    }

    /// Remove the `k`-th behavior of extent `(off, len)` in place
    /// (order-preserving shift; the vacated tail slot is freed).
    pub fn remove_at(&mut self, off: u32, len: u32, k: u32) -> Behavior {
        debug_assert!(k < len);
        let b = self.pool[(off + k) as usize];
        for j in k..len - 1 {
            self.pool[(off + j) as usize] = self.pool[(off + j + 1) as usize];
        }
        self.free_extent(off + len - 1, 1);
        b
    }

    /// Begin a compaction pass: swap the pool out (returned to the caller
    /// for reading old extents), reset the free list. Pair with
    /// [`end_compaction`](Self::end_compaction).
    pub(crate) fn begin_compaction(&mut self) -> Vec<Behavior> {
        let mut old = std::mem::take(&mut self.spare);
        old.clear();
        std::mem::swap(&mut old, &mut self.pool);
        self.free.clear();
        self.live = 0;
        old
    }

    /// Append one agent's extent during compaction; returns its offset.
    pub(crate) fn append_extent(&mut self, bs: &[Behavior]) -> u32 {
        let off = self.pool.len() as u32;
        self.pool.extend_from_slice(bs);
        self.live += bs.len() as u32;
        off
    }

    /// Finish a compaction pass, keeping the old pool's capacity as the
    /// spare buffer for the next pass.
    pub(crate) fn end_compaction(&mut self, mut old: Vec<Behavior>) {
        old.clear();
        self.spare = old;
    }

    /// Bytes held by the arena (pool + spare + free list), for memory
    /// accounting — this replaces the old per-agent `Vec` capacity sums.
    pub fn approx_bytes(&self) -> u64 {
        ((self.pool.capacity() + self.spare.capacity()) * std::mem::size_of::<Behavior>()
            + self.free.capacity() * std::mem::size_of::<(u32, u32)>()) as u64
    }

    /// Check the partition invariant against the owner's columns: live
    /// extents + free extents tile the pool exactly, without overlap.
    /// Test/debug aid; O(n log n).
    pub fn check_coherent(&self, live_extents: impl Iterator<Item = (u32, u32)>) {
        let mut ext: Vec<(u32, u32, bool)> =
            live_extents.filter(|&(_, l)| l > 0).map(|(o, l)| (o, l, true)).collect();
        let live_sum: u32 = ext.iter().map(|e| e.1).sum();
        assert_eq!(live_sum, self.live, "live count mismatch");
        ext.extend(self.free.iter().map(|&(o, l)| (o, l, false)));
        ext.sort_unstable();
        let mut cursor = 0u32;
        for (o, l, _) in ext {
            assert_eq!(o, cursor, "gap or overlap at pool offset {o}");
            cursor = o + l;
        }
        assert_eq!(cursor as usize, self.pool.len(), "pool tail not covered");
    }
}

/// Shared hot columns handed to each behavior-sweep closure invocation
/// (read-only snapshot of the pre-sweep state; indexed by slot).
pub struct SweepCols<'a> {
    pub pos: &'a [Vec3],
    pub diam: &'a [f64],
    pub kind: &'a [AgentKind],
    pub gid: &'a [GlobalId],
}

/// Mutable raw pointer into the arena pool, shared across sweep workers.
/// Sound because live extents are pairwise disjoint and each live id is
/// visited exactly once (see [`ResourceManager::behavior_sweep`]).
struct PoolPtr(*mut Behavior);
unsafe impl Send for PoolPtr {}
unsafe impl Sync for PoolPtr {}

/// Mutable agent borrow that writes the hot-path SoA columns back on drop,
/// so arbitrary model mutations keep the mirror coherent.
pub struct AgentRefMut<'a> {
    agent: &'a mut Agent,
    pos: &'a mut Vec3,
    diam: &'a mut f64,
    kind: &'a mut AgentKind,
    gid: &'a mut GlobalId,
    nref: &'a mut AgentPointer,
}

impl Deref for AgentRefMut<'_> {
    type Target = Agent;

    #[inline]
    fn deref(&self) -> &Agent {
        self.agent
    }
}

impl DerefMut for AgentRefMut<'_> {
    #[inline]
    fn deref_mut(&mut self) -> &mut Agent {
        self.agent
    }
}

impl Drop for AgentRefMut<'_> {
    #[inline]
    fn drop(&mut self) {
        *self.pos = self.agent.position;
        *self.diam = self.agent.diameter;
        *self.kind = self.agent.kind;
        *self.gid = self.agent.global_id;
        *self.nref = self.agent.neighbor_ref;
    }
}

/// Per-rank agent container.
///
/// # Example: add, read through the SoA mirror, sort
///
/// ```
/// use teraagent::core::agent::{Agent, Behavior, CellType};
/// use teraagent::core::resource_manager::ResourceManager;
/// use teraagent::util::Vec3;
///
/// let mut rm = ResourceManager::new(0);
/// let id = rm.add(Agent::cell(Vec3::new(30.0, 2.0, 2.0), 10.0, CellType::A));
/// let _far = rm.add(Agent::cell(Vec3::new(90.0, 2.0, 2.0), 10.0, CellType::B));
///
/// // Hot reads come from the contiguous SoA columns…
/// assert_eq!(rm.col_position(id.index), Vec3::new(30.0, 2.0, 2.0));
/// // …which mutations through the write-back guard keep coherent.
/// rm.get_mut(id).unwrap().diameter = 12.5;
/// assert_eq!(rm.col_diameter(id.index), 12.5);
///
/// // Behaviors live in the manager's flat arena, not on the agent.
/// rm.attach_behavior(id, Behavior::RandomWalk { speed: 2.0 });
/// assert_eq!(rm.behaviors(id).unwrap().len(), 1);
///
/// // The periodic Morton sort (§2.5) reassigns local ids: stale ids
/// // stop resolving, agents, global ids and behaviors survive.
/// rm.sort_by_position(Vec3::ZERO, 10.0);
/// assert!(rm.get(id).is_none());
/// assert_eq!(rm.len(), 2);
/// assert_eq!(rm.arena().live_len(), 1);
/// ```
#[derive(Debug)]
pub struct ResourceManager {
    /// Slot vector: `slots[local_id.index]`.
    slots: Vec<Option<Agent>>,
    /// Current reuse counter per slot (incremented on free).
    reuse: Vec<u32>,
    /// Free slot indices available for reuse.
    free: Vec<u32>,
    /// Number of live (owned) agents.
    live: usize,
    /// SoA mirror of the hot attributes, indexed by slot.
    pos_col: Vec<Vec3>,
    diam_col: Vec<f64>,
    kind_col: Vec<AgentKind>,
    /// Exchange-path mirror columns: global id, agent reference and
    /// behavior extent — everything the columnar TA IO writer needs to
    /// assemble an `AgentBlock` (and stream its behavior children) without
    /// reading the `Agent` struct.
    gid_col: Vec<GlobalId>,
    ref_col: Vec<AgentPointer>,
    /// Behavior extent offset per slot (into the arena pool).
    beh_off_col: Vec<u32>,
    /// Behavior extent length per slot (the writer's `nbeh` column).
    nbeh_col: Vec<u32>,
    /// Flat pool of all owned agents' behaviors.
    arena: BehaviorArena,
    /// Aura agents (read-only copies of neighbor-rank agents).
    aura: Vec<Agent>,
    /// GlobalId -> owned slot index, for pointer resolution.
    global_map: HashMap<GlobalId, u32>,
    /// Issues global ids on demand.
    pub id_source: GlobalIdSource,
}

impl ResourceManager {
    pub fn new(rank: u32) -> Self {
        ResourceManager {
            slots: Vec::new(),
            reuse: Vec::new(),
            free: Vec::new(),
            live: 0,
            pos_col: Vec::new(),
            diam_col: Vec::new(),
            kind_col: Vec::new(),
            gid_col: Vec::new(),
            ref_col: Vec::new(),
            beh_off_col: Vec::new(),
            nbeh_col: Vec::new(),
            arena: BehaviorArena::new(),
            aura: Vec::new(),
            global_map: HashMap::new(),
            id_source: GlobalIdSource::new(rank),
        }
    }

    /// Number of live owned agents.
    #[inline]
    pub fn len(&self) -> usize {
        self.live
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Number of slots (capacity view; includes holes).
    pub fn slot_count(&self) -> usize {
        self.slots.len()
    }

    /// Add an agent with no behaviors, assigning its local id.
    pub fn add(&mut self, agent: Agent) -> LocalId {
        self.add_with_behaviors(agent, &[])
    }

    /// Add an agent together with its behavior set (copied into the
    /// arena). Returns the assigned local id.
    pub fn add_with_behaviors(&mut self, agent: Agent, behaviors: &[Behavior]) -> LocalId {
        let off = self.arena.alloc(behaviors);
        self.add_inner(agent, off, behaviors.len() as u32)
    }

    /// Add an agent, filling its behavior extent from an iterator — the
    /// wire-ingest path (behavior blocks decode straight into the arena,
    /// no intermediate `Vec`).
    pub fn add_with_behaviors_from(
        &mut self,
        agent: Agent,
        behaviors: impl ExactSizeIterator<Item = Behavior>,
    ) -> LocalId {
        let (off, len) = self.arena.alloc_from(behaviors);
        self.add_inner(agent, off, len)
    }

    fn add_inner(&mut self, mut agent: Agent, beh_off: u32, beh_len: u32) -> LocalId {
        let index = match self.free.pop() {
            Some(i) => i,
            None => {
                self.slots.push(None);
                self.reuse.push(0);
                self.pos_col.push(Vec3::ZERO);
                self.diam_col.push(0.0);
                self.kind_col.push(KIND_FILL);
                self.gid_col.push(GlobalId::UNSET);
                self.ref_col.push(AgentPointer::NULL);
                self.beh_off_col.push(0);
                self.nbeh_col.push(0);
                (self.slots.len() - 1) as u32
            }
        };
        let id = LocalId::new(index, self.reuse[index as usize]);
        agent.local_id = id;
        if agent.global_id.is_set() {
            self.global_map.insert(agent.global_id, index);
        }
        debug_assert!(self.slots[index as usize].is_none());
        self.pos_col[index as usize] = agent.position;
        self.diam_col[index as usize] = agent.diameter;
        self.kind_col[index as usize] = agent.kind;
        self.gid_col[index as usize] = agent.global_id;
        self.ref_col[index as usize] = agent.neighbor_ref;
        self.beh_off_col[index as usize] = beh_off;
        self.nbeh_col[index as usize] = beh_len;
        self.slots[index as usize] = Some(agent);
        self.live += 1;
        id
    }

    /// Remove an agent by local id; returns it if the id was live. The
    /// agent's behavior extent returns to the arena free list.
    pub fn remove(&mut self, id: LocalId) -> Option<Agent> {
        let idx = id.index as usize;
        if idx >= self.slots.len() || self.reuse[idx] != id.reuse {
            return None;
        }
        let agent = self.slots[idx].take()?;
        self.arena.free_extent(self.beh_off_col[idx], self.nbeh_col[idx]);
        self.beh_off_col[idx] = 0;
        self.nbeh_col[idx] = 0;
        // Bump reuse so stale ids can't resolve; recycle the slot. (The
        // SoA columns keep their now-stale values; only live ids read
        // them.)
        self.reuse[idx] = self.reuse[idx].wrapping_add(1);
        self.free.push(id.index);
        self.live -= 1;
        if agent.global_id.is_set() {
            self.global_map.remove(&agent.global_id);
        }
        Some(agent)
    }

    /// Borrow an agent by local id (None if stale or freed).
    #[inline]
    pub fn get(&self, id: LocalId) -> Option<&Agent> {
        let idx = id.index as usize;
        if idx >= self.slots.len() || self.reuse[idx] != id.reuse {
            return None;
        }
        self.slots[idx].as_ref()
    }

    /// Mutably borrow an agent by local id. The returned guard derefs to
    /// `Agent` and flushes the hot-path columns when dropped.
    #[inline]
    pub fn get_mut(&mut self, id: LocalId) -> Option<AgentRefMut<'_>> {
        let idx = id.index as usize;
        if idx >= self.slots.len() || self.reuse[idx] != id.reuse {
            return None;
        }
        let agent = self.slots[idx].as_mut()?;
        Some(AgentRefMut {
            agent,
            pos: &mut self.pos_col[idx],
            diam: &mut self.diam_col[idx],
            kind: &mut self.kind_col[idx],
            gid: &mut self.gid_col[idx],
            nref: &mut self.ref_col[idx],
        })
    }

    /// O(1) position write-through: updates the agent and the `pos`
    /// column without materializing a guard (the mechanics apply loop and
    /// `World::move_agent` fast path). Returns `false` for stale ids.
    #[inline]
    pub fn set_position(&mut self, id: LocalId, pos: Vec3) -> bool {
        let idx = id.index as usize;
        if idx >= self.slots.len() || self.reuse[idx] != id.reuse {
            return false;
        }
        match self.slots[idx].as_mut() {
            Some(a) => {
                a.position = pos;
                self.pos_col[idx] = pos;
                true
            }
            None => false,
        }
    }

    // ----- SoA mirror reads ------------------------------------------------

    /// Contiguous position column (indexed by slot; stale for holes).
    #[inline]
    pub fn positions(&self) -> &[Vec3] {
        &self.pos_col
    }

    /// Contiguous diameter column (indexed by slot; stale for holes).
    #[inline]
    pub fn diameters(&self) -> &[f64] {
        &self.diam_col
    }

    /// Contiguous kind column (indexed by slot; stale for holes). The
    /// kind payload carries the per-class adhesion coefficient.
    #[inline]
    pub fn kinds(&self) -> &[AgentKind] {
        &self.kind_col
    }

    /// Position of the agent in slot `index` (must be live).
    #[inline]
    pub fn col_position(&self, index: u32) -> Vec3 {
        self.pos_col[index as usize]
    }

    /// Diameter of the agent in slot `index` (must be live).
    #[inline]
    pub fn col_diameter(&self, index: u32) -> f64 {
        self.diam_col[index as usize]
    }

    /// Kind of the agent in slot `index` (must be live).
    #[inline]
    pub fn col_kind(&self, index: u32) -> AgentKind {
        self.kind_col[index as usize]
    }

    /// Column view for the TA IO SoA-direct encoder. Slots of freed
    /// agents hold stale values; callers index only through live ids.
    /// Behavior tails stream straight from the arena pool through the
    /// `beh_off`/`nbeh` extent columns — no per-slot indirection.
    #[inline]
    pub fn columns(&self) -> ColumnSource<'_> {
        ColumnSource {
            pos: &self.pos_col,
            diam: &self.diam_col,
            kind: &self.kind_col,
            gid: &self.gid_col,
            nref: &self.ref_col,
            nbeh: &self.nbeh_col,
            beh_off: &self.beh_off_col,
            beh: self.arena.pool(),
        }
    }

    // ----- behavior arena --------------------------------------------------

    /// The behavior arena (read view).
    #[inline]
    pub fn arena(&self) -> &BehaviorArena {
        &self.arena
    }

    /// Behavior slice of the agent in slot `index` (empty for holes) —
    /// an O(1) arena extent lookup.
    #[inline]
    pub fn behaviors_of_slot(&self, index: u32) -> &[Behavior] {
        let i = index as usize;
        if self.slots[i].is_none() {
            return &[];
        }
        self.arena.slice(self.beh_off_col[i], self.nbeh_col[i])
    }

    /// Behavior slice of a live agent (None if the id is stale).
    #[inline]
    pub fn behaviors(&self, id: LocalId) -> Option<&[Behavior]> {
        let idx = id.index as usize;
        if idx >= self.slots.len() || self.reuse[idx] != id.reuse || self.slots[idx].is_none() {
            return None;
        }
        Some(self.arena.slice(self.beh_off_col[idx], self.nbeh_col[idx]))
    }

    /// Mutable behavior slice of a live agent (in-place parameter
    /// mutation; the extent length cannot change through this view —
    /// use [`attach_behavior`](Self::attach_behavior) /
    /// [`detach_behavior`](Self::detach_behavior) for that).
    #[inline]
    pub fn behaviors_mut(&mut self, id: LocalId) -> Option<&mut [Behavior]> {
        let idx = id.index as usize;
        if idx >= self.slots.len() || self.reuse[idx] != id.reuse || self.slots[idx].is_none() {
            return None;
        }
        Some(self.arena.slice_mut(self.beh_off_col[idx], self.nbeh_col[idx]))
    }

    /// Append a behavior to a live agent's set (extent grows in place
    /// when possible, else relocates within the arena). Returns `false`
    /// for stale ids.
    pub fn attach_behavior(&mut self, id: LocalId, b: Behavior) -> bool {
        let idx = id.index as usize;
        if idx >= self.slots.len() || self.reuse[idx] != id.reuse || self.slots[idx].is_none() {
            return false;
        }
        let (off, len) = (self.beh_off_col[idx], self.nbeh_col[idx]);
        self.beh_off_col[idx] = self.arena.grow_extent(off, len, b);
        self.nbeh_col[idx] = len + 1;
        true
    }

    /// Remove the `k`-th behavior of a live agent (order-preserving).
    /// Returns the removed behavior, or None for stale ids / bad index.
    pub fn detach_behavior(&mut self, id: LocalId, k: usize) -> Option<Behavior> {
        let idx = id.index as usize;
        if idx >= self.slots.len() || self.reuse[idx] != id.reuse || self.slots[idx].is_none() {
            return None;
        }
        let (off, len) = (self.beh_off_col[idx], self.nbeh_col[idx]);
        if k as u32 >= len {
            return None;
        }
        let b = self.arena.remove_at(off, len, k as u32);
        self.nbeh_col[idx] = len - 1;
        if len == 1 {
            self.beh_off_col[idx] = 0;
        }
        Some(b)
    }

    /// Replace a live agent's behavior set wholesale. Returns `false` for
    /// stale ids.
    pub fn set_behaviors(&mut self, id: LocalId, bs: &[Behavior]) -> bool {
        let idx = id.index as usize;
        if idx >= self.slots.len() || self.reuse[idx] != id.reuse || self.slots[idx].is_none() {
            return false;
        }
        let (off, len) = (self.beh_off_col[idx], self.nbeh_col[idx]);
        if len as usize == bs.len() {
            self.arena.slice_mut(off, len).copy_from_slice(bs);
            return true;
        }
        self.arena.free_extent(off, len);
        self.beh_off_col[idx] = self.arena.alloc(bs);
        self.nbeh_col[idx] = bs.len() as u32;
        true
    }

    /// Total live behaviors across all owned agents.
    #[inline]
    pub fn behavior_count(&self) -> usize {
        self.arena.live_len() as usize
    }

    /// Run `f` over every id in `ids` that carries behaviors, in parallel
    /// chunks, handing each invocation the shared pre-sweep hot columns
    /// and a **mutable** view of that agent's arena extent (in-place
    /// parameter updates are free; structural changes are returned as
    /// effects `E` and applied serially by the caller). Effects come back
    /// flattened in `ids` order regardless of thread count — chunk
    /// boundaries only partition the index space — so the sweep is
    /// bit-deterministic at any parallelism.
    ///
    /// Safety: live extents are pairwise disjoint (arena partition
    /// invariant) and `ids` contains unique live ids, so each extent is
    /// mutably borrowed by exactly one closure invocation.
    pub fn behavior_sweep<E: Send>(
        &mut self,
        pool: &ThreadPool,
        ids: &[LocalId],
        f: impl Fn(usize, LocalId, &SweepCols<'_>, &mut [Behavior]) -> Option<E> + Sync,
    ) -> (Vec<E>, f64) {
        let ptr = PoolPtr(self.arena.pool.as_mut_ptr());
        let cols = SweepCols {
            pos: &self.pos_col,
            diam: &self.diam_col,
            kind: &self.kind_col,
            gid: &self.gid_col,
        };
        let beh_off = &self.beh_off_col;
        let beh_len = &self.nbeh_col;
        let ptr = &ptr;
        let (chunks, cpu) = pool.map_chunks_timed(ids.len(), |_c, s, e| {
            let mut out: Vec<E> = Vec::new();
            for k in s..e {
                let id = ids[k];
                let i = id.index as usize;
                let len = beh_len[i] as usize;
                if len == 0 {
                    continue;
                }
                let off = beh_off[i] as usize;
                // SAFETY: disjoint live extents, unique ids (see above).
                let bs = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(off), len) };
                if let Some(eff) = f(k, id, &cols, bs) {
                    out.push(eff);
                }
            }
            out
        });
        (chunks.into_iter().flatten().collect(), cpu)
    }

    // -----------------------------------------------------------------------

    /// Resolve an agent by *global* id (owned agents only). This is the
    /// `AgentPointer` indirection: global id -> map -> reference.
    pub fn get_by_global(&self, gid: GlobalId) -> Option<&Agent> {
        let idx = *self.global_map.get(&gid)?;
        self.slots[idx as usize].as_ref()
    }

    /// Ensure the agent has a global id (generated on demand, §2.5) and
    /// return it.
    pub fn ensure_global_id(&mut self, id: LocalId) -> Option<GlobalId> {
        let idx = id.index as usize;
        if idx >= self.slots.len() || self.reuse[idx] != id.reuse {
            return None;
        }
        // Split borrow: take id_source before the slot borrow.
        let agent = self.slots[idx].as_mut()?;
        if !agent.global_id.is_set() {
            agent.global_id = self.id_source.next();
            self.global_map.insert(agent.global_id, id.index);
            self.gid_col[idx] = agent.global_id;
        }
        Some(agent.global_id)
    }

    /// Iterate live owned agents.
    pub fn iter(&self) -> impl Iterator<Item = &Agent> {
        self.slots.iter().filter_map(|s| s.as_ref())
    }

    /// Live local ids (snapshot, slot order).
    pub fn ids(&self) -> Vec<LocalId> {
        self.iter().map(|a| a.local_id).collect()
    }

    /// Append live local ids into `out` (slot order) — the
    /// allocation-free variant for per-iteration scratch reuse.
    pub fn collect_ids(&self, out: &mut Vec<LocalId>) {
        out.reserve(self.live); // no-op once the buffer reached steady state
        for a in self.iter() {
            out.push(a.local_id);
        }
    }

    // ----- aura ------------------------------------------------------------

    /// Replace the aura set (rebuilt each iteration, §2.2.1 Deallocation).
    pub fn set_aura(&mut self, agents: Vec<Agent>) {
        self.aura = agents;
    }

    pub fn clear_aura(&mut self) {
        self.aura.clear();
    }

    pub fn aura(&self) -> &[Agent] {
        &self.aura
    }

    pub fn aura_mut(&mut self) -> &mut Vec<Agent> {
        &mut self.aura
    }

    // ----- sorting ----------------------------------------------------------

    /// Agent sorting (§2.5): reorder agents so that agents close in space
    /// are close in memory (Morton order), improving cache hit rate. All
    /// agents move to fresh slots; local ids are reassigned; this is also
    /// the point where buffers of migrated-in agents are compacted away
    /// (the paper's deferred-deallocation story). The SoA mirror is
    /// rebuilt in the same pass, so after sorting the hot columns stream
    /// in Morton order too — and the behavior arena is re-packed in the
    /// new slot order (extents contiguous, free list empty), restoring
    /// perfect traversal locality for the sweep and the columnar writer.
    pub fn sort_by_position(&mut self, origin: Vec3, cell: f64) {
        self.resort(|a| morton3(a.position - origin, cell));
    }

    /// [`sort_by_position`](Self::sort_by_position) with the quantized
    /// coordinates **clamped to `dims`** — the exact cell mapping of a
    /// `NeighborSearchGrid` with the same origin, cell size and logical
    /// dims (see [`morton3_in_grid`]). After this sort, slot order is
    /// non-decreasing in the grid's Morton cell index even for positions
    /// at or beyond the far domain edge, which is the precondition for
    /// the grid's parallel wholesale rebuild
    /// (`NeighborSearchGrid::rebuild_owned`).
    pub fn sort_by_grid(&mut self, origin: Vec3, cell: f64, dims: [usize; 3]) {
        self.resort(|a| morton3_in_grid(a.position - origin, cell, dims));
    }

    /// Shared resort body: drain, order by `key`, rebuild storage, the
    /// SoA mirror and the behavior arena from scratch.
    fn resort(&mut self, key: impl Fn(&Agent) -> u64) {
        let old_pool = self.arena.begin_compaction();
        let mut agents: Vec<(Agent, u32, u32)> = Vec::with_capacity(self.live);
        for (i, s) in self.slots.iter_mut().enumerate() {
            if let Some(a) = s.take() {
                agents.push((a, self.beh_off_col[i], self.nbeh_col[i]));
            }
        }
        agents.sort_by_key(|(a, _, _)| key(a));
        // Rebuild storage from scratch; reuse counters keep increasing per
        // slot so stale ids remain invalid.
        for r in self.reuse.iter_mut() {
            *r = r.wrapping_add(1);
        }
        self.slots.clear();
        self.slots.resize_with(agents.len(), || None);
        self.reuse.resize(agents.len().max(self.reuse.len()), 0);
        self.pos_col.clear();
        self.pos_col.resize(agents.len(), Vec3::ZERO);
        self.diam_col.clear();
        self.diam_col.resize(agents.len(), 0.0);
        self.kind_col.clear();
        self.kind_col.resize(agents.len(), KIND_FILL);
        self.gid_col.clear();
        self.gid_col.resize(agents.len(), GlobalId::UNSET);
        self.ref_col.clear();
        self.ref_col.resize(agents.len(), AgentPointer::NULL);
        self.beh_off_col.clear();
        self.beh_off_col.resize(agents.len(), 0);
        self.nbeh_col.clear();
        self.nbeh_col.resize(agents.len(), 0);
        self.free.clear();
        self.global_map.clear();
        self.live = 0;
        let reuse_snapshot: Vec<u32> = self.reuse.clone();
        for (i, (mut a, old_off, beh_len)) in agents.into_iter().enumerate() {
            let id = LocalId::new(i as u32, reuse_snapshot[i]);
            a.local_id = id;
            if a.global_id.is_set() {
                self.global_map.insert(a.global_id, i as u32);
            }
            self.pos_col[i] = a.position;
            self.diam_col[i] = a.diameter;
            self.kind_col[i] = a.kind;
            self.gid_col[i] = a.global_id;
            self.ref_col[i] = a.neighbor_ref;
            self.beh_off_col[i] = self
                .arena
                .append_extent(&old_pool[old_off as usize..(old_off + beh_len) as usize]);
            self.nbeh_col[i] = beh_len;
            self.slots[i] = Some(a);
            self.live += 1;
        }
        self.arena.end_compaction(old_pool);
    }

    /// Approximate live bytes of this container (for memory accounting).
    /// Behavior memory is the arena's pool + free-list footprint
    /// ([`BehaviorArena::approx_bytes`]) — there are no per-agent heap
    /// blocks to sum anymore.
    pub fn approx_bytes(&self) -> u64 {
        let slot_bytes = self.slots.capacity() * std::mem::size_of::<Option<Agent>>();
        let aux = self.reuse.capacity() * 4
            + self.free.capacity() * 4
            + self.pos_col.capacity() * std::mem::size_of::<Vec3>()
            + self.diam_col.capacity() * 8
            + self.kind_col.capacity() * std::mem::size_of::<AgentKind>()
            + self.gid_col.capacity() * std::mem::size_of::<GlobalId>()
            + self.ref_col.capacity() * std::mem::size_of::<AgentPointer>()
            + self.beh_off_col.capacity() * 4
            + self.nbeh_col.capacity() * 4
            + self.global_map.len() * (std::mem::size_of::<GlobalId>() + 8);
        let aura = self.aura.capacity() * std::mem::size_of::<Agent>();
        (slot_bytes + aux + aura) as u64 + self.arena.approx_bytes()
    }

    /// Assert the arena partition invariant (test/debug aid).
    pub fn check_arena_coherent(&self) {
        self.arena.check_coherent(
            self.slots
                .iter()
                .enumerate()
                .filter(|(_, s)| s.is_some())
                .map(|(i, _)| (self.beh_off_col[i], self.nbeh_col[i])),
        );
    }
}

/// 3D Morton (Z-order) key of a position quantized to `cell`-sized bins.
/// 21 bits per axis (enough for 2M cells per axis).
pub fn morton3(p: Vec3, cell: f64) -> u64 {
    let q = |v: f64| -> u64 {
        let i = (v / cell).max(0.0) as u64;
        i.min((1 << 21) - 1)
    };
    interleave3(q(p.x)) | (interleave3(q(p.y)) << 1) | (interleave3(q(p.z)) << 2)
}

/// Per-axis grid bin of a coordinate relative to the grid origin: the
/// **single** quantizer shared by the agent sort key
/// ([`morton3_in_grid`]) and the NSG's cell map
/// (`space::nsg::CellMap::coords_of`). The parallel NSG rebuild's fast
/// path requires those two to agree bit-for-bit — slot order must be
/// non-decreasing in cell index after `sort_by_grid` — so the formula
/// lives in exactly one place. Do not fork it.
#[inline]
pub fn grid_axis_bin(v: f64, cell: f64, d: usize) -> usize {
    if v <= 0.0 {
        0
    } else {
        ((v / cell) as usize).min(d - 1)
    }
}

/// [`morton3`] with each axis quantized by [`grid_axis_bin`] — the exact
/// cell coordinate of a `NeighborSearchGrid` with the same origin, cell
/// size and logical dims — so ordering by this key orders agents by
/// their grid cell's Morton index. `p` is the position *relative to the
/// grid origin* (`position - bounds.min`), as in [`morton3`]. Axes are
/// additionally saturated at the 21-bit interleave width (the NSG caps
/// its dims there too, so the saturation never diverges from the grid).
pub fn morton3_in_grid(p: Vec3, cell: f64, dims: [usize; 3]) -> u64 {
    let q = |v: f64, d: usize| -> u64 {
        (grid_axis_bin(v, cell, d) as u64).min((1 << 21) - 1)
    };
    interleave3(q(p.x, dims[0]))
        | (interleave3(q(p.y, dims[1])) << 1)
        | (interleave3(q(p.z, dims[2])) << 2)
}

/// Spread the low 21 bits of `v` so consecutive bits are 3 apart.
fn interleave3(mut v: u64) -> u64 {
    v &= 0x1F_FFFF;
    v = (v | (v << 32)) & 0x1F00000000FFFF;
    v = (v | (v << 16)) & 0x1F0000FF0000FF;
    v = (v | (v << 8)) & 0x100F00F00F00F00F;
    v = (v | (v << 4)) & 0x10C30C30C30C30C3;
    v = (v | (v << 2)) & 0x1249249249249249;
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::agent::CellType;

    fn mk(pos: Vec3) -> Agent {
        Agent::cell(pos, 10.0, CellType::A)
    }

    #[test]
    fn add_get_remove() {
        let mut rm = ResourceManager::new(0);
        let id = rm.add(mk(Vec3::new(1.0, 2.0, 3.0)));
        assert_eq!(rm.len(), 1);
        assert_eq!(rm.get(id).unwrap().position, Vec3::new(1.0, 2.0, 3.0));
        let a = rm.remove(id).unwrap();
        assert_eq!(a.local_id, id);
        assert_eq!(rm.len(), 0);
        assert!(rm.get(id).is_none());
    }

    #[test]
    fn slot_reuse_bumps_counter() {
        let mut rm = ResourceManager::new(0);
        let id1 = rm.add(mk(Vec3::ZERO));
        rm.remove(id1).unwrap();
        let id2 = rm.add(mk(Vec3::ZERO));
        assert_eq!(id1.index, id2.index, "slot should be reused");
        assert_ne!(id1.reuse, id2.reuse, "reuse counter must differ");
        assert!(rm.get(id1).is_none(), "stale id must not resolve");
        assert!(rm.get(id2).is_some());
    }

    #[test]
    fn stale_id_mutation_refused() {
        let mut rm = ResourceManager::new(0);
        let id1 = rm.add(mk(Vec3::ZERO));
        rm.remove(id1);
        rm.add(mk(Vec3::ZERO));
        assert!(rm.get_mut(id1).is_none());
        assert!(rm.remove(id1).is_none());
        assert!(!rm.set_position(id1, Vec3::splat(1.0)));
        assert!(rm.behaviors(id1).is_none());
        assert!(!rm.attach_behavior(id1, Behavior::Divide));
        assert!(rm.detach_behavior(id1, 0).is_none());
    }

    #[test]
    fn global_id_on_demand() {
        let mut rm = ResourceManager::new(7);
        let id = rm.add(mk(Vec3::ZERO));
        assert!(!rm.get(id).unwrap().global_id.is_set());
        let gid = rm.ensure_global_id(id).unwrap();
        assert_eq!(gid.rank, 7);
        // Idempotent.
        assert_eq!(rm.ensure_global_id(id).unwrap(), gid);
        assert_eq!(rm.get_by_global(gid).unwrap().local_id, id);
    }

    #[test]
    fn iter_counts_live_only() {
        let mut rm = ResourceManager::new(0);
        let a = rm.add(mk(Vec3::ZERO));
        let _b = rm.add(mk(Vec3::ZERO));
        rm.remove(a);
        assert_eq!(rm.iter().count(), 1);
        assert_eq!(rm.len(), 1);
    }

    #[test]
    fn aura_replaced_wholesale() {
        let mut rm = ResourceManager::new(0);
        rm.set_aura(vec![mk(Vec3::ZERO), mk(Vec3::ZERO)]);
        assert_eq!(rm.aura().len(), 2);
        rm.set_aura(vec![mk(Vec3::ZERO)]);
        assert_eq!(rm.aura().len(), 1);
        rm.clear_aura();
        assert!(rm.aura().is_empty());
    }

    #[test]
    fn sort_preserves_agents_and_invalidates_old_ids() {
        let mut rm = ResourceManager::new(0);
        let mut ids = Vec::new();
        for i in 0..50 {
            ids.push(rm.add(mk(Vec3::new((50 - i) as f64, 0.0, 0.0))));
        }
        let gid = rm.ensure_global_id(ids[10]).unwrap();
        rm.sort_by_position(Vec3::ZERO, 1.0);
        assert_eq!(rm.len(), 50);
        // Old ids are stale now.
        assert!(rm.get(ids[0]).is_none());
        // Global id still resolves.
        assert!(rm.get_by_global(gid).is_some());
        // Positions are sorted along x (Morton of (x,0,0) is monotone in x).
        let xs: Vec<f64> = rm.iter().map(|a| a.position.x).collect();
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(xs, sorted);
        // The SoA mirror was rebuilt in the same order.
        for a in rm.iter() {
            assert_eq!(rm.col_position(a.local_id.index), a.position);
            assert_eq!(rm.col_diameter(a.local_id.index), a.diameter);
        }
    }

    #[test]
    fn morton_orders_locality() {
        // Near points should compare closer than far points along the curve.
        let a = morton3(Vec3::new(0.0, 0.0, 0.0), 1.0);
        let b = morton3(Vec3::new(1.0, 0.0, 0.0), 1.0);
        let far = morton3(Vec3::new(1000.0, 1000.0, 1000.0), 1.0);
        assert!(b > a);
        assert!(far > b);
        // Negative coordinates clamp to 0, never panic.
        let _ = morton3(Vec3::new(-5.0, -5.0, -5.0), 1.0);
    }

    #[test]
    fn approx_bytes_nonzero_when_populated() {
        let mut rm = ResourceManager::new(0);
        for _ in 0..10 {
            rm.add(mk(Vec3::ZERO));
        }
        assert!(rm.approx_bytes() > 0);
    }

    #[test]
    fn approx_bytes_tracks_arena_not_agents() {
        let mut rm = ResourceManager::new(0);
        let id = rm.add(mk(Vec3::ZERO));
        let base = rm.approx_bytes();
        // Attaching enough behaviors to force a pool allocation must show
        // up in the container accounting (via the arena), even though the
        // Agent struct itself never changes size.
        for _ in 0..64 {
            rm.attach_behavior(id, Behavior::Divide);
        }
        assert!(rm.approx_bytes() > base);
        assert_eq!(rm.arena().live_len(), 64);
        assert!(rm.arena().approx_bytes() >= 64 * std::mem::size_of::<Behavior>() as u64);
    }

    // ----- SoA mirror coherence --------------------------------------------

    #[test]
    fn soa_mirror_tracks_add_and_set_position() {
        let mut rm = ResourceManager::new(0);
        let id = rm.add(mk(Vec3::new(1.0, 2.0, 3.0)));
        assert_eq!(rm.col_position(id.index), Vec3::new(1.0, 2.0, 3.0));
        assert_eq!(rm.col_diameter(id.index), 10.0);
        assert!(rm.set_position(id, Vec3::new(4.0, 5.0, 6.0)));
        assert_eq!(rm.col_position(id.index), Vec3::new(4.0, 5.0, 6.0));
        assert_eq!(rm.get(id).unwrap().position, Vec3::new(4.0, 5.0, 6.0));
        assert_eq!(rm.positions().len(), rm.slot_count());
        assert_eq!(rm.diameters().len(), rm.slot_count());
        assert_eq!(rm.kinds().len(), rm.slot_count());
    }

    #[test]
    fn soa_mirror_flushes_on_guard_drop() {
        let mut rm = ResourceManager::new(0);
        let id = rm.add(mk(Vec3::ZERO));
        {
            let mut a = rm.get_mut(id).unwrap();
            a.position = Vec3::new(7.0, 8.0, 9.0);
            a.diameter = 3.5;
            a.kind = AgentKind::Cell { cell_type: CellType::B, adhesion: 0.9 };
        } // guard drop flushes the columns
        assert_eq!(rm.col_position(id.index), Vec3::new(7.0, 8.0, 9.0));
        assert_eq!(rm.col_diameter(id.index), 3.5);
        assert!(matches!(
            rm.col_kind(id.index),
            AgentKind::Cell { cell_type: CellType::B, .. }
        ));
        // A second mutation through a fresh guard also flushes.
        {
            let mut a = rm.get_mut(id).unwrap();
            a.diameter = 4.25;
        }
        assert_eq!(rm.col_diameter(id.index), 4.25);
    }

    #[test]
    fn soa_mirror_after_slot_recycling() {
        let mut rm = ResourceManager::new(0);
        let a = rm.add(mk(Vec3::splat(1.0)));
        rm.remove(a).unwrap();
        let b = rm.add(mk(Vec3::splat(2.0)));
        assert_eq!(a.index, b.index);
        assert_eq!(rm.col_position(b.index), Vec3::splat(2.0));
    }

    #[test]
    fn exchange_columns_track_mutations() {
        let mut rm = ResourceManager::new(4);
        let id = rm.add(mk(Vec3::ZERO));
        let cols = rm.columns();
        assert_eq!(cols.gid[id.index as usize], crate::core::ids::GlobalId::UNSET);
        assert_eq!(cols.nbeh[id.index as usize], 0);
        // ensure_global_id writes through to the gid column.
        let gid = rm.ensure_global_id(id).unwrap();
        assert_eq!(rm.columns().gid[id.index as usize], gid);
        // Attach writes the extent columns; the guard flushes neighbor ref.
        let target = crate::core::ids::GlobalId::new(1, 9);
        rm.attach_behavior(id, crate::core::agent::Behavior::Divide);
        {
            let mut a = rm.get_mut(id).unwrap();
            a.neighbor_ref = AgentPointer::to(target);
        }
        assert_eq!(rm.columns().nbeh[id.index as usize], 1);
        assert_eq!(rm.columns().nref[id.index as usize].target, target);
        assert_eq!(rm.behaviors_of_slot(id.index).len(), 1);
        // Sorting rebuilds the exchange columns coherently.
        rm.sort_by_position(Vec3::ZERO, 1.0);
        let a = rm.iter().next().unwrap();
        let idx = a.local_id.index as usize;
        assert_eq!(rm.columns().gid[idx], gid);
        assert_eq!(rm.columns().nbeh[idx], 1);
        assert_eq!(rm.columns().nref[idx].target, target);
        assert_eq!(rm.behaviors_of_slot(a.local_id.index), &[Behavior::Divide]);
        rm.check_arena_coherent();
    }

    #[test]
    fn collect_ids_reuses_buffer() {
        let mut rm = ResourceManager::new(0);
        for _ in 0..5 {
            rm.add(mk(Vec3::ZERO));
        }
        let mut buf = Vec::new();
        rm.collect_ids(&mut buf);
        assert_eq!(buf.len(), 5);
        let cap = buf.capacity();
        buf.clear();
        rm.collect_ids(&mut buf);
        assert_eq!(buf.len(), 5);
        assert_eq!(buf.capacity(), cap, "steady-state collect must not realloc");
        assert_eq!(buf, rm.ids());
    }

    // ----- behavior arena --------------------------------------------------

    #[test]
    fn arena_alloc_free_coalesce_truncate() {
        let mut ar = BehaviorArena::new();
        let a = ar.alloc(&[Behavior::Divide, Behavior::Divide]);
        let b = ar.alloc(&[Behavior::RandomWalk { speed: 1.0 }]);
        let c = ar.alloc(&[Behavior::Divide; 3]);
        assert_eq!((a, b, c), (0, 2, 3));
        assert_eq!(ar.live_len(), 6);
        assert_eq!(ar.pool_len(), 6);
        // Free the middle extent: parked on the free list.
        ar.free_extent(b, 1);
        assert_eq!(ar.free_extents(), 1);
        assert_eq!(ar.pool_len(), 6);
        // Free the tail extent: coalesces with the parked hole and the
        // whole merged span ends the pool, so it truncates away.
        ar.free_extent(c, 3);
        assert_eq!(ar.free_extents(), 0);
        assert_eq!(ar.pool_len(), 2);
        assert_eq!(ar.live_len(), 2);
        // Free the head extent: pool fully returns.
        ar.free_extent(a, 2);
        assert_eq!(ar.pool_len(), 0);
        assert_eq!(ar.live_len(), 0);
    }

    #[test]
    fn arena_first_fit_reuses_hole() {
        let mut ar = BehaviorArena::new();
        let a = ar.alloc(&[Behavior::Divide; 3]);
        let _b = ar.alloc(&[Behavior::Divide; 2]);
        ar.free_extent(a, 3);
        assert_eq!(ar.free_extents(), 1);
        // A 2-slot alloc fits in the 3-slot hole (split, prefix reused).
        let c = ar.alloc(&[Behavior::RandomWalk { speed: 2.0 }; 2]);
        assert_eq!(c, 0);
        assert_eq!(ar.pool_len(), 5, "no growth while a fitting hole exists");
        assert_eq!(ar.free_extents(), 1);
        // The remaining 1-slot hole serves a 1-slot alloc exactly.
        let d = ar.alloc(&[Behavior::Divide]);
        assert_eq!(d, 2);
        assert_eq!(ar.free_extents(), 0);
        assert_eq!(ar.pool_len(), 5);
    }

    #[test]
    fn attach_detach_roundtrip() {
        let mut rm = ResourceManager::new(0);
        let id = rm.add(mk(Vec3::ZERO));
        let other = rm.add(mk(Vec3::ZERO));
        rm.attach_behavior(other, Behavior::Divide); // interleave extents
        assert!(rm.attach_behavior(id, Behavior::Growth { rate: 1.0, max_diameter: 2.0 }));
        assert!(rm.attach_behavior(id, Behavior::RandomWalk { speed: 0.5 }));
        assert!(rm.attach_behavior(id, Behavior::Divide));
        assert_eq!(rm.behaviors(id).unwrap().len(), 3);
        rm.check_arena_coherent();
        // Detach the middle one: order of the rest is preserved.
        let removed = rm.detach_behavior(id, 1).unwrap();
        assert_eq!(removed, Behavior::RandomWalk { speed: 0.5 });
        assert_eq!(
            rm.behaviors(id).unwrap(),
            &[Behavior::Growth { rate: 1.0, max_diameter: 2.0 }, Behavior::Divide]
        );
        assert_eq!(rm.behavior_count(), 3); // 2 here + 1 on `other`
        rm.check_arena_coherent();
        // In-place parameter mutation through the mutable slice.
        if let Behavior::Growth { rate, .. } = &mut rm.behaviors_mut(id).unwrap()[0] {
            *rate = 9.0;
        }
        assert!(matches!(rm.behaviors(id).unwrap()[0], Behavior::Growth { rate, .. } if rate == 9.0));
        // Wholesale replacement with a different length reallocates.
        assert!(rm.set_behaviors(id, &[Behavior::Divide]));
        assert_eq!(rm.behaviors(id).unwrap(), &[Behavior::Divide]);
        rm.check_arena_coherent();
        // Removing the agent frees its extent.
        rm.remove(id).unwrap();
        assert_eq!(rm.behavior_count(), 1);
        rm.check_arena_coherent();
    }

    #[test]
    fn sort_compacts_arena_in_slot_order() {
        let mut rm = ResourceManager::new(0);
        // Reverse-x agents with distinct behavior counts (i % 3).
        for i in 0..30u32 {
            let id = rm.add(mk(Vec3::new((30 - i) as f64, 0.0, 0.0)));
            for _ in 0..(i % 3) {
                rm.attach_behavior(id, Behavior::RandomWalk { speed: i as f64 });
            }
        }
        // Churn a few holes into the pool.
        let ids = rm.ids();
        rm.remove(ids[4]);
        rm.remove(ids[17]);
        let live_behaviors = rm.behavior_count();
        rm.sort_by_position(Vec3::ZERO, 1.0);
        rm.check_arena_coherent();
        // After the sort the pool is exactly the live behaviors, extents
        // are contiguous in slot order, and the free list is empty.
        assert_eq!(rm.arena().pool_len(), live_behaviors);
        assert_eq!(rm.arena().free_extents(), 0);
        let mut cursor = 0u32;
        for a in rm.iter() {
            let i = a.local_id.index as usize;
            assert_eq!(rm.columns().beh_off[i], cursor);
            cursor += rm.columns().nbeh[i];
            // Extent contents follow the agent (speed == original x key).
            for b in rm.behaviors_of_slot(a.local_id.index) {
                assert!(matches!(b, Behavior::RandomWalk { speed } if (30.0 - speed) == a.position.x));
            }
        }
    }

    #[test]
    fn behavior_sweep_mutates_in_place_and_orders_effects() {
        let mut rm = ResourceManager::new(0);
        let mut expect = Vec::new();
        for i in 0..40u32 {
            let id = rm.add(mk(Vec3::new(i as f64, 0.0, 0.0)));
            if i % 2 == 0 {
                rm.attach_behavior(id, Behavior::RandomWalk { speed: i as f64 });
                expect.push(i as f64);
            }
        }
        let ids = rm.ids();
        for threads in [1usize, 2, 8] {
            let pool = ThreadPool::new(threads);
            let (effects, _cpu) = rm.behavior_sweep(&pool, &ids, |_k, _id, cols, bs| {
                let mut out = None;
                for b in bs.iter_mut() {
                    if let Behavior::RandomWalk { speed } = b {
                        out = Some(*speed);
                        *speed += 0.0; // in-place mutation is allowed
                        let _ = cols.pos; // columns are readable
                    }
                }
                out
            });
            assert_eq!(effects, expect, "effects must come back in ids order");
        }
    }
}
