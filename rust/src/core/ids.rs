//! Agent identifiers (§2.5 of the paper).
//!
//! BioDynaMo addresses agents through a *local* identifier
//! `⟨index, reuse_counter⟩`: `index` slots into a vector-based map (cheap,
//! lock-free adds/removes to distinct elements) and `reuse_counter`
//! disambiguates reuse of a freed slot. Distribution breaks the "indices are
//! almost contiguous" invariant (migrated/aura agents arrive with foreign
//! indices), so TeraAgent adds a *global* identifier `⟨rank, counter⟩` that
//! is constant for the agent's lifetime and is generated lazily — only when
//! an agent first crosses a rank boundary or is checkpointed.

use std::fmt;

/// Local identifier: unique among *active* agents of one rank.
///
/// Invariant: at any time at most one active agent holds a given `index`;
/// when a slot is reused, `reuse` is incremented, so the full pair is unique
/// across the rank's history.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct LocalId {
    pub index: u32,
    pub reuse: u32,
}

impl LocalId {
    pub const INVALID: LocalId = LocalId { index: u32::MAX, reuse: u32::MAX };

    #[inline]
    pub fn new(index: u32, reuse: u32) -> Self {
        LocalId { index, reuse }
    }

    #[inline]
    pub fn is_valid(self) -> bool {
        self != LocalId::INVALID
    }

    /// Pack into a u64 (index in the high half, reuse in the low half).
    #[inline]
    pub fn pack(self) -> u64 {
        ((self.index as u64) << 32) | self.reuse as u64
    }

    #[inline]
    pub fn unpack(v: u64) -> Self {
        LocalId { index: (v >> 32) as u32, reuse: v as u32 }
    }
}

impl fmt::Display for LocalId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "L⟨{},{}⟩", self.index, self.reuse)
    }
}

/// Global identifier: `⟨creating rank, strictly increasing counter⟩`.
/// Constant for the whole simulation; never reused.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct GlobalId {
    pub rank: u32,
    pub counter: u64,
}

impl GlobalId {
    /// Sentinel "not yet assigned" value. Global ids are generated on
    /// demand (first migration / aura transfer / checkpoint).
    pub const UNSET: GlobalId = GlobalId { rank: u32::MAX, counter: u64::MAX };

    #[inline]
    pub fn new(rank: u32, counter: u64) -> Self {
        GlobalId { rank, counter }
    }

    #[inline]
    pub fn is_set(self) -> bool {
        self != GlobalId::UNSET
    }
}

impl fmt::Display for GlobalId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "G⟨r{},{}⟩", self.rank, self.counter)
    }
}

/// Issues global identifiers for one rank.
#[derive(Clone, Debug)]
pub struct GlobalIdSource {
    rank: u32,
    next: u64,
}

impl GlobalIdSource {
    pub fn new(rank: u32) -> Self {
        GlobalIdSource { rank, next: 0 }
    }

    #[inline]
    pub fn next(&mut self) -> GlobalId {
        let id = GlobalId::new(self.rank, self.next);
        self.next += 1;
        id
    }

    pub fn issued(&self) -> u64 {
        self.next
    }
}

/// Smart pointer to another agent (§2.2, observation 1).
///
/// Stores the pointee's *global* identifier instead of a raw address, so
/// serializing an `AgentPointer` reduces to serializing the id; the raw
/// reference is re-obtained from the [`ResourceManager`] map on access.
/// Only `const` (read) access is exposed, matching the paper's restriction
/// that avoids merging divergent replicas across ranks.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct AgentPointer {
    pub target: GlobalId,
}

impl AgentPointer {
    pub const NULL: AgentPointer = AgentPointer { target: GlobalId::UNSET };

    #[inline]
    pub fn to(target: GlobalId) -> Self {
        AgentPointer { target }
    }

    #[inline]
    pub fn is_null(self) -> bool {
        !self.target.is_set()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_id_pack_round_trip() {
        let id = LocalId::new(0xDEAD_BEEF, 42);
        assert_eq!(LocalId::unpack(id.pack()), id);
    }

    #[test]
    fn local_id_invalid_flag() {
        assert!(!LocalId::INVALID.is_valid());
        assert!(LocalId::new(0, 0).is_valid());
    }

    #[test]
    fn global_id_source_strictly_increasing() {
        let mut src = GlobalIdSource::new(3);
        let a = src.next();
        let b = src.next();
        assert_eq!(a.rank, 3);
        assert_eq!(b.counter, a.counter + 1);
        assert_eq!(src.issued(), 2);
    }

    #[test]
    fn global_id_unset_sentinel() {
        assert!(!GlobalId::UNSET.is_set());
        assert!(GlobalId::new(0, 0).is_set());
    }

    #[test]
    fn agent_pointer_null() {
        assert!(AgentPointer::NULL.is_null());
        let p = AgentPointer::to(GlobalId::new(1, 7));
        assert!(!p.is_null());
        assert_eq!(p.target, GlobalId::new(1, 7));
    }

    #[test]
    fn ids_are_ordered() {
        assert!(LocalId::new(1, 0) < LocalId::new(2, 0));
        assert!(GlobalId::new(0, 5) < GlobalId::new(1, 0));
    }
}
