//! The agent data model.
//!
//! An [`Agent`] is a fixed-layout header (ids, position, diameter, kind
//! payload) plus a variable-length list of [`Behavior`]s — the same
//! block-tree shape (Fig. 2A of the paper: agent node with 0..n behavior
//! children) that [TeraAgent IO](crate::io::ta_io) serializes by in-order
//! traversal. "Polymorphism" (the paper's virtual classes) is enum-based:
//! [`AgentKind`] carries the per-class payload, and its discriminant plays
//! the role of the *class id written in place of the vtable pointer*.

use super::ids::{AgentPointer, GlobalId, LocalId};
use crate::util::Vec3;

/// Cell type for the clustering / sorting models.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CellType {
    A,
    B,
}

impl CellType {
    pub fn code(self) -> u8 {
        match self {
            CellType::A => 0,
            CellType::B => 1,
        }
    }

    pub fn from_code(c: u8) -> CellType {
        if c == 0 { CellType::A } else { CellType::B }
    }
}

/// SIR compartment for the epidemiology model.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SirState {
    Susceptible,
    Infected,
    Recovered,
}

impl SirState {
    pub fn code(self) -> u8 {
        match self {
            SirState::Susceptible => 0,
            SirState::Infected => 1,
            SirState::Recovered => 2,
        }
    }

    pub fn from_code(c: u8) -> SirState {
        match c {
            0 => SirState::Susceptible,
            1 => SirState::Infected,
            _ => SirState::Recovered,
        }
    }
}

/// Per-class agent payload (the "most derived class" of the paper).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AgentKind {
    /// Plain spherical cell used by clustering / proliferation.
    Cell {
        cell_type: CellType,
        /// Adhesion coefficient towards same-type neighbors.
        adhesion: f64,
    },
    /// Proliferating cell: grows, divides above a volume threshold.
    GrowingCell {
        volume: f64,
        growth_rate: f64,
        division_volume: f64,
    },
    /// A person in the epidemiology model.
    Person {
        state: SirState,
        /// Iterations since infection (0 when not infected).
        infected_for: u32,
    },
    /// Tumor cell for the oncology model.
    TumorCell {
        /// Cell-cycle progress in [0, 1); division at 1.
        cycle: f64,
        /// Probability per iteration to be quiescent (no growth).
        quiescent: bool,
    },
}

impl AgentKind {
    /// Stable class id — written to the wire in place of the vtable pointer.
    pub fn class_id(&self) -> u16 {
        match self {
            AgentKind::Cell { .. } => 1,
            AgentKind::GrowingCell { .. } => 2,
            AgentKind::Person { .. } => 3,
            AgentKind::TumorCell { .. } => 4,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AgentKind::Cell { .. } => "Cell",
            AgentKind::GrowingCell { .. } => "GrowingCell",
            AgentKind::Person { .. } => "Person",
            AgentKind::TumorCell { .. } => "TumorCell",
        }
    }
}

/// A behavior attached to an agent (the paper's behavior objects; the
/// variable-length children of the agent's block tree).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Behavior {
    /// Deterministic diameter growth up to a maximum.
    Growth { rate: f64, max_diameter: f64 },
    /// Division when volume exceeds a threshold (GrowingCell).
    Divide,
    /// Brownian random walk.
    RandomWalk { speed: f64 },
    /// SIR infection dynamics (Person).
    Infection {
        radius: f64,
        prob: f64,
        recovery_iters: u32,
    },
    /// Tumor growth + division cycle (TumorCell).
    TumorGrowth { cycle_rate: f64, max_diameter: f64 },
}

impl Behavior {
    /// Stable class id for serialization.
    pub fn class_id(&self) -> u16 {
        match self {
            Behavior::Growth { .. } => 1,
            Behavior::Divide => 2,
            Behavior::RandomWalk { .. } => 3,
            Behavior::Infection { .. } => 4,
            Behavior::TumorGrowth { .. } => 5,
        }
    }
}

/// An agent: fixed-layout header + behavior list (+ optional const pointer
/// to another agent, exercising the [`AgentPointer`] indirection).
#[derive(Clone, Debug, PartialEq)]
pub struct Agent {
    /// Local identifier on the owning rank; reassigned on migration.
    pub local_id: LocalId,
    /// Global identifier, generated lazily (UNSET until first transfer).
    pub global_id: GlobalId,
    pub position: Vec3,
    pub diameter: f64,
    pub kind: AgentKind,
    pub behaviors: Vec<Behavior>,
    /// Optional reference to another agent (e.g. mother cell); const-only.
    pub neighbor_ref: AgentPointer,
}

impl Agent {
    /// New cell of the given type at a position.
    pub fn cell(position: Vec3, diameter: f64, cell_type: CellType) -> Agent {
        Agent {
            local_id: LocalId::INVALID,
            global_id: GlobalId::UNSET,
            position,
            diameter,
            kind: AgentKind::Cell { cell_type, adhesion: 0.4 },
            behaviors: Vec::new(),
            neighbor_ref: AgentPointer::NULL,
        }
    }

    /// New growing/dividing cell.
    pub fn growing_cell(position: Vec3, diameter: f64) -> Agent {
        let volume = sphere_volume(diameter);
        Agent {
            local_id: LocalId::INVALID,
            global_id: GlobalId::UNSET,
            position,
            diameter,
            kind: AgentKind::GrowingCell {
                volume,
                growth_rate: volume * 0.05,
                division_volume: volume * 2.0,
            },
            behaviors: vec![Behavior::Growth { rate: 1.0, max_diameter: diameter * 2.0 }, Behavior::Divide],
            neighbor_ref: AgentPointer::NULL,
        }
    }

    /// New person for the epidemiology model.
    pub fn person(position: Vec3, state: SirState) -> Agent {
        Agent {
            local_id: LocalId::INVALID,
            global_id: GlobalId::UNSET,
            position,
            diameter: 1.0,
            kind: AgentKind::Person { state, infected_for: 0 },
            behaviors: vec![
                Behavior::RandomWalk { speed: 1.0 },
                Behavior::Infection { radius: 1.0, prob: 0.05, recovery_iters: 50 },
            ],
            neighbor_ref: AgentPointer::NULL,
        }
    }

    /// New tumor cell.
    pub fn tumor_cell(position: Vec3, diameter: f64) -> Agent {
        Agent {
            local_id: LocalId::INVALID,
            global_id: GlobalId::UNSET,
            position,
            diameter,
            kind: AgentKind::TumorCell { cycle: 0.0, quiescent: false },
            behaviors: vec![Behavior::TumorGrowth { cycle_rate: 0.04, max_diameter: diameter * 1.26 }],
            neighbor_ref: AgentPointer::NULL,
        }
    }

    /// Sphere volume from the current diameter.
    pub fn volume(&self) -> f64 {
        sphere_volume(self.diameter)
    }

    /// Approximate heap size of this agent (header + behavior block).
    pub fn approx_bytes(&self) -> u64 {
        (std::mem::size_of::<Agent>() + self.behaviors.capacity() * std::mem::size_of::<Behavior>())
            as u64
    }
}

/// Volume of a sphere with the given diameter.
#[inline]
pub fn sphere_volume(diameter: f64) -> f64 {
    std::f64::consts::PI / 6.0 * diameter * diameter * diameter
}

/// Diameter of a sphere with the given volume.
#[inline]
pub fn sphere_diameter(volume: f64) -> f64 {
    (6.0 * volume / std::f64::consts::PI).cbrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_set_kind() {
        let c = Agent::cell(Vec3::ZERO, 10.0, CellType::B);
        assert_eq!(c.kind.class_id(), 1);
        assert!(matches!(c.kind, AgentKind::Cell { cell_type: CellType::B, .. }));
        let g = Agent::growing_cell(Vec3::ZERO, 10.0);
        assert_eq!(g.kind.class_id(), 2);
        assert_eq!(g.behaviors.len(), 2);
        let p = Agent::person(Vec3::ZERO, SirState::Infected);
        assert_eq!(p.kind.class_id(), 3);
        let t = Agent::tumor_cell(Vec3::ZERO, 10.0);
        assert_eq!(t.kind.class_id(), 4);
    }

    #[test]
    fn class_ids_are_distinct() {
        let kinds = [
            Agent::cell(Vec3::ZERO, 1.0, CellType::A).kind.class_id(),
            Agent::growing_cell(Vec3::ZERO, 1.0).kind.class_id(),
            Agent::person(Vec3::ZERO, SirState::Susceptible).kind.class_id(),
            Agent::tumor_cell(Vec3::ZERO, 1.0).kind.class_id(),
        ];
        let mut sorted = kinds.to_vec();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), kinds.len());
    }

    #[test]
    fn sphere_volume_diameter_round_trip() {
        let d = 12.34;
        let v = sphere_volume(d);
        assert!((sphere_diameter(v) - d).abs() < 1e-9);
        // unit sphere: d=2 -> 4/3 π
        assert!((sphere_volume(2.0) - 4.0 / 3.0 * std::f64::consts::PI).abs() < 1e-12);
    }

    #[test]
    fn sir_codes_round_trip() {
        for s in [SirState::Susceptible, SirState::Infected, SirState::Recovered] {
            assert_eq!(SirState::from_code(s.code()), s);
        }
        for t in [CellType::A, CellType::B] {
            assert_eq!(CellType::from_code(t.code()), t);
        }
    }

    #[test]
    fn approx_bytes_counts_behaviors() {
        let mut a = Agent::cell(Vec3::ZERO, 1.0, CellType::A);
        let base = a.approx_bytes();
        a.behaviors.push(Behavior::Divide);
        assert!(a.approx_bytes() > base);
    }

    #[test]
    fn behavior_class_ids_distinct() {
        let ids = [
            Behavior::Growth { rate: 0.0, max_diameter: 0.0 }.class_id(),
            Behavior::Divide.class_id(),
            Behavior::RandomWalk { speed: 0.0 }.class_id(),
            Behavior::Infection { radius: 0.0, prob: 0.0, recovery_iters: 0 }.class_id(),
            Behavior::TumorGrowth { cycle_rate: 0.0, max_diameter: 0.0 }.class_id(),
        ];
        let mut s = ids.to_vec();
        s.sort();
        s.dedup();
        assert_eq!(s.len(), ids.len());
    }
}
