//! The agent data model.
//!
//! An [`Agent`] is a fixed-layout header (ids, position, diameter, kind
//! payload). Its variable-length list of [`Behavior`]s — the same
//! block-tree shape (Fig. 2A of the paper: agent node with 0..n behavior
//! children) that [TeraAgent IO](crate::io::ta_io) serializes by in-order
//! traversal — does NOT live on the agent: behaviors are pool-allocated in
//! the [`BehaviorArena`](crate::core::resource_manager::BehaviorArena)
//! owned by the `ResourceManager`, addressed by per-slot offset/length
//! columns. The header itself is `Copy`; an agent in flight between ranks
//! travels with its behavior slice in an [`AgentBatch`].
//!
//! "Polymorphism" (the paper's virtual classes) is enum-based:
//! [`AgentKind`] carries the per-class payload, and its discriminant plays
//! the role of the *class id written in place of the vtable pointer*.

use super::ids::{AgentPointer, GlobalId, LocalId};
use crate::util::Vec3;

/// Cell type for the clustering / sorting models.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CellType {
    A,
    B,
}

impl CellType {
    pub fn code(self) -> u8 {
        match self {
            CellType::A => 0,
            CellType::B => 1,
        }
    }

    pub fn from_code(c: u8) -> CellType {
        if c == 0 { CellType::A } else { CellType::B }
    }
}

/// SIR compartment for the epidemiology model.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SirState {
    Susceptible,
    Infected,
    Recovered,
}

impl SirState {
    pub fn code(self) -> u8 {
        match self {
            SirState::Susceptible => 0,
            SirState::Infected => 1,
            SirState::Recovered => 2,
        }
    }

    pub fn from_code(c: u8) -> SirState {
        match c {
            0 => SirState::Susceptible,
            1 => SirState::Infected,
            _ => SirState::Recovered,
        }
    }
}

/// Per-class agent payload (the "most derived class" of the paper).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AgentKind {
    /// Plain spherical cell used by clustering / proliferation.
    Cell {
        cell_type: CellType,
        /// Adhesion coefficient towards same-type neighbors.
        adhesion: f64,
    },
    /// Proliferating cell: grows, divides above a volume threshold.
    GrowingCell {
        volume: f64,
        growth_rate: f64,
        division_volume: f64,
    },
    /// A person in the epidemiology model.
    Person {
        state: SirState,
        /// Iterations since infection (0 when not infected).
        infected_for: u32,
    },
    /// Tumor cell for the oncology model.
    TumorCell {
        /// Cell-cycle progress in [0, 1); division at 1.
        cycle: f64,
        /// Probability per iteration to be quiescent (no growth).
        quiescent: bool,
    },
    /// A citizen in the social-dynamics model: carries wealth and a
    /// reputation score that behaviors (Trade / Reputation) evolve.
    Citizen { wealth: f64, reputation: f64 },
}

impl AgentKind {
    /// Stable class id — written to the wire in place of the vtable pointer.
    pub fn class_id(&self) -> u16 {
        match self {
            AgentKind::Cell { .. } => 1,
            AgentKind::GrowingCell { .. } => 2,
            AgentKind::Person { .. } => 3,
            AgentKind::TumorCell { .. } => 4,
            AgentKind::Citizen { .. } => 5,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AgentKind::Cell { .. } => "Cell",
            AgentKind::GrowingCell { .. } => "GrowingCell",
            AgentKind::Person { .. } => "Person",
            AgentKind::TumorCell { .. } => "TumorCell",
            AgentKind::Citizen { .. } => "Citizen",
        }
    }
}

/// A behavior attached to an agent (the paper's behavior objects; the
/// variable-length children of the agent's block tree).
///
/// Behaviors live in the
/// [`BehaviorArena`](crate::core::resource_manager::BehaviorArena), not on
/// the agent, so the type is deliberately `Copy`: arena compaction and
/// extent relocation are plain memmoves.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Behavior {
    /// Deterministic diameter growth up to a maximum.
    Growth { rate: f64, max_diameter: f64 },
    /// Division when volume exceeds a threshold (GrowingCell).
    Divide,
    /// Brownian random walk.
    RandomWalk { speed: f64 },
    /// SIR infection dynamics (Person).
    Infection {
        radius: f64,
        prob: f64,
        recovery_iters: u32,
    },
    /// Tumor growth + division cycle (TumorCell).
    TumorGrowth { cycle_rate: f64, max_diameter: f64 },
    /// Wealth exchange with nearby citizens; `cooldown` iterations of
    /// rest after each trade (Citizen).
    Trade { radius: f64, gain: f64, cooldown: u32 },
    /// Reputation tracking toward wealth (Citizen).
    Reputation { score: f64, decay: f64 },
}

impl Behavior {
    /// Stable class id for serialization.
    pub fn class_id(&self) -> u16 {
        match self {
            Behavior::Growth { .. } => 1,
            Behavior::Divide => 2,
            Behavior::RandomWalk { .. } => 3,
            Behavior::Infection { .. } => 4,
            Behavior::TumorGrowth { .. } => 5,
            Behavior::Trade { .. } => 6,
            Behavior::Reputation { .. } => 7,
        }
    }
}

/// An agent header: fixed layout, `Copy` (+ optional const pointer
/// to another agent, exercising the [`AgentPointer`] indirection).
///
/// Behaviors are NOT stored here — they live in the owning
/// `ResourceManager`'s behavior arena (or alongside the header in an
/// [`AgentBatch`] while in transit).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Agent {
    /// Local identifier on the owning rank; reassigned on migration.
    pub local_id: LocalId,
    /// Global identifier, generated lazily (UNSET until first transfer).
    pub global_id: GlobalId,
    pub position: Vec3,
    pub diameter: f64,
    pub kind: AgentKind,
    /// Optional reference to another agent (e.g. mother cell); const-only.
    pub neighbor_ref: AgentPointer,
}

impl Agent {
    /// New cell of the given type at a position.
    pub fn cell(position: Vec3, diameter: f64, cell_type: CellType) -> Agent {
        Agent {
            local_id: LocalId::INVALID,
            global_id: GlobalId::UNSET,
            position,
            diameter,
            kind: AgentKind::Cell { cell_type, adhesion: 0.4 },
            neighbor_ref: AgentPointer::NULL,
        }
    }

    /// New growing/dividing cell. Attach [`growing_cell_behaviors`] when
    /// the cell should grow/divide through the behavior sweep.
    pub fn growing_cell(position: Vec3, diameter: f64) -> Agent {
        let volume = sphere_volume(diameter);
        Agent {
            local_id: LocalId::INVALID,
            global_id: GlobalId::UNSET,
            position,
            diameter,
            kind: AgentKind::GrowingCell {
                volume,
                growth_rate: volume * 0.05,
                division_volume: volume * 2.0,
            },
            neighbor_ref: AgentPointer::NULL,
        }
    }

    /// New person for the epidemiology model. Attach
    /// [`person_behaviors`] when SIR dynamics should run in the sweep.
    pub fn person(position: Vec3, state: SirState) -> Agent {
        Agent {
            local_id: LocalId::INVALID,
            global_id: GlobalId::UNSET,
            position,
            diameter: 1.0,
            kind: AgentKind::Person { state, infected_for: 0 },
            neighbor_ref: AgentPointer::NULL,
        }
    }

    /// New tumor cell. Attach [`tumor_cell_behaviors`] for cycle dynamics.
    pub fn tumor_cell(position: Vec3, diameter: f64) -> Agent {
        Agent {
            local_id: LocalId::INVALID,
            global_id: GlobalId::UNSET,
            position,
            diameter,
            kind: AgentKind::TumorCell { cycle: 0.0, quiescent: false },
            neighbor_ref: AgentPointer::NULL,
        }
    }

    /// New citizen for the social-dynamics model.
    pub fn citizen(position: Vec3, wealth: f64) -> Agent {
        Agent {
            local_id: LocalId::INVALID,
            global_id: GlobalId::UNSET,
            position,
            diameter: 1.0,
            kind: AgentKind::Citizen { wealth, reputation: 0.0 },
            neighbor_ref: AgentPointer::NULL,
        }
    }

    /// Sphere volume from the current diameter.
    pub fn volume(&self) -> f64 {
        sphere_volume(self.diameter)
    }

    /// Approximate size of this agent header. Behaviors are accounted by
    /// the owning arena
    /// ([`BehaviorArena::approx_bytes`](crate::core::resource_manager::BehaviorArena::approx_bytes)),
    /// not per agent.
    pub fn approx_bytes(&self) -> u64 {
        std::mem::size_of::<Agent>() as u64
    }
}

/// The behavior set historically attached by `Agent::growing_cell`.
pub fn growing_cell_behaviors(diameter: f64) -> [Behavior; 2] {
    [Behavior::Growth { rate: 1.0, max_diameter: diameter * 2.0 }, Behavior::Divide]
}

/// The behavior set historically attached by `Agent::person`.
pub fn person_behaviors() -> [Behavior; 2] {
    [
        Behavior::RandomWalk { speed: 1.0 },
        Behavior::Infection { radius: 1.0, prob: 0.05, recovery_iters: 50 },
    ]
}

/// The behavior set historically attached by `Agent::tumor_cell`.
pub fn tumor_cell_behaviors(diameter: f64) -> [Behavior; 1] {
    [Behavior::TumorGrowth { cycle_rate: 0.04, max_diameter: diameter * 1.26 }]
}

/// A set of agents in transit (checkpoint restore, spawn queue, owned
/// decode) together with their behavior slices, stored flat: one
/// `Vec<Behavior>` pool and a prefix-offset column — the same
/// traversal-ordered layout as the wire and the arena, so batch ↔ arena
/// moves are slice copies.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct AgentBatch {
    /// Agent headers, in batch order.
    pub agents: Vec<Agent>,
    beh: Vec<Behavior>,
    /// Prefix offsets into `beh`; `off.len() == agents.len() + 1`.
    off: Vec<u32>,
}

impl AgentBatch {
    pub fn new() -> AgentBatch {
        AgentBatch { agents: Vec::new(), beh: Vec::new(), off: vec![0] }
    }

    pub fn with_capacity(n: usize) -> AgentBatch {
        let mut off = Vec::with_capacity(n + 1);
        off.push(0);
        AgentBatch { agents: Vec::with_capacity(n), beh: Vec::new(), off }
    }

    /// Wrap behavior-less agents.
    pub fn from_agents(agents: Vec<Agent>) -> AgentBatch {
        let off = vec![0; agents.len() + 1];
        AgentBatch { agents, beh: Vec::new(), off }
    }

    pub fn len(&self) -> usize {
        self.agents.len()
    }

    pub fn is_empty(&self) -> bool {
        self.agents.is_empty()
    }

    /// Append an agent with its behavior slice.
    pub fn push(&mut self, agent: Agent, behaviors: &[Behavior]) {
        self.agents.push(agent);
        self.beh.extend_from_slice(behaviors);
        self.off.push(self.beh.len() as u32);
    }

    /// Append an agent, filling its behaviors from an iterator.
    pub fn push_from(&mut self, agent: Agent, behaviors: impl Iterator<Item = Behavior>) {
        self.agents.push(agent);
        self.beh.extend(behaviors);
        self.off.push(self.beh.len() as u32);
    }

    /// The behavior slice of batch entry `i`.
    pub fn behaviors(&self, i: usize) -> &[Behavior] {
        &self.beh[self.off[i] as usize..self.off[i + 1] as usize]
    }

    /// Total behaviors across all entries.
    pub fn behavior_count(&self) -> usize {
        self.beh.len()
    }

    /// Iterate `(header, behavior slice)` pairs in batch order.
    pub fn iter(&self) -> impl Iterator<Item = (&Agent, &[Behavior])> {
        self.agents
            .iter()
            .enumerate()
            .map(move |(i, a)| (a, &self.beh[self.off[i] as usize..self.off[i + 1] as usize]))
    }

    pub fn clear(&mut self) {
        self.agents.clear();
        self.beh.clear();
        self.off.clear();
        self.off.push(0);
    }

    /// Keep only entries whose header satisfies `f`, compacting the
    /// behavior pool in place (stable order).
    pub fn retain(&mut self, mut f: impl FnMut(&Agent) -> bool) {
        let mut w = 0usize;
        let mut bw = 0usize;
        for i in 0..self.agents.len() {
            if f(&self.agents[i]) {
                let (s, e) = (self.off[i] as usize, self.off[i + 1] as usize);
                self.agents[w] = self.agents[i];
                self.off[w] = bw as u32;
                for j in s..e {
                    self.beh[bw] = self.beh[j];
                    bw += 1;
                }
                w += 1;
            }
        }
        self.agents.truncate(w);
        self.beh.truncate(bw);
        self.off.truncate(w);
        self.off.push(bw as u32);
    }

    /// Move all entries of `other` to the end of `self`.
    pub fn append(&mut self, other: &mut AgentBatch) {
        for i in 0..other.len() {
            let a = other.agents[i];
            self.agents.push(a);
            self.beh
                .extend_from_slice(&other.beh[other.off[i] as usize..other.off[i + 1] as usize]);
            self.off.push(self.beh.len() as u32);
        }
        other.clear();
    }
}

/// Volume of a sphere with the given diameter.
#[inline]
pub fn sphere_volume(diameter: f64) -> f64 {
    std::f64::consts::PI / 6.0 * diameter * diameter * diameter
}

/// Diameter of a sphere with the given volume.
#[inline]
pub fn sphere_diameter(volume: f64) -> f64 {
    (6.0 * volume / std::f64::consts::PI).cbrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_set_kind() {
        let c = Agent::cell(Vec3::ZERO, 10.0, CellType::B);
        assert_eq!(c.kind.class_id(), 1);
        assert!(matches!(c.kind, AgentKind::Cell { cell_type: CellType::B, .. }));
        let g = Agent::growing_cell(Vec3::ZERO, 10.0);
        assert_eq!(g.kind.class_id(), 2);
        assert_eq!(growing_cell_behaviors(10.0).len(), 2);
        let p = Agent::person(Vec3::ZERO, SirState::Infected);
        assert_eq!(p.kind.class_id(), 3);
        let t = Agent::tumor_cell(Vec3::ZERO, 10.0);
        assert_eq!(t.kind.class_id(), 4);
        let z = Agent::citizen(Vec3::ZERO, 5.0);
        assert_eq!(z.kind.class_id(), 5);
    }

    #[test]
    fn class_ids_are_distinct() {
        let kinds = [
            Agent::cell(Vec3::ZERO, 1.0, CellType::A).kind.class_id(),
            Agent::growing_cell(Vec3::ZERO, 1.0).kind.class_id(),
            Agent::person(Vec3::ZERO, SirState::Susceptible).kind.class_id(),
            Agent::tumor_cell(Vec3::ZERO, 1.0).kind.class_id(),
            Agent::citizen(Vec3::ZERO, 1.0).kind.class_id(),
        ];
        let mut sorted = kinds.to_vec();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), kinds.len());
    }

    #[test]
    fn sphere_volume_diameter_round_trip() {
        let d = 12.34;
        let v = sphere_volume(d);
        assert!((sphere_diameter(v) - d).abs() < 1e-9);
        // unit sphere: d=2 -> 4/3 π
        assert!((sphere_volume(2.0) - 4.0 / 3.0 * std::f64::consts::PI).abs() < 1e-12);
    }

    #[test]
    fn sir_codes_round_trip() {
        for s in [SirState::Susceptible, SirState::Infected, SirState::Recovered] {
            assert_eq!(SirState::from_code(s.code()), s);
        }
        for t in [CellType::A, CellType::B] {
            assert_eq!(CellType::from_code(t.code()), t);
        }
    }

    #[test]
    fn agent_header_is_fixed_size() {
        // Behaviors live in the arena; the header's reported footprint must
        // not depend on any behavior set.
        let a = Agent::cell(Vec3::ZERO, 1.0, CellType::A);
        assert_eq!(a.approx_bytes(), std::mem::size_of::<Agent>() as u64);
    }

    #[test]
    fn behavior_class_ids_distinct() {
        let ids = [
            Behavior::Growth { rate: 0.0, max_diameter: 0.0 }.class_id(),
            Behavior::Divide.class_id(),
            Behavior::RandomWalk { speed: 0.0 }.class_id(),
            Behavior::Infection { radius: 0.0, prob: 0.0, recovery_iters: 0 }.class_id(),
            Behavior::TumorGrowth { cycle_rate: 0.0, max_diameter: 0.0 }.class_id(),
            Behavior::Trade { radius: 0.0, gain: 0.0, cooldown: 0 }.class_id(),
            Behavior::Reputation { score: 0.0, decay: 0.0 }.class_id(),
        ];
        let mut s = ids.to_vec();
        s.sort();
        s.dedup();
        assert_eq!(s.len(), ids.len());
    }

    #[test]
    fn batch_push_retain_append() {
        let mut b = AgentBatch::new();
        b.push(Agent::cell(Vec3::ZERO, 1.0, CellType::A), &[]);
        b.push(Agent::person(Vec3::new(1.0, 0.0, 0.0), SirState::Susceptible), &person_behaviors());
        b.push(Agent::tumor_cell(Vec3::new(2.0, 0.0, 0.0), 3.0), &tumor_cell_behaviors(3.0));
        assert_eq!(b.len(), 3);
        assert_eq!(b.behaviors(0).len(), 0);
        assert_eq!(b.behaviors(1).len(), 2);
        assert_eq!(b.behaviors(2).len(), 1);
        assert_eq!(b.behavior_count(), 3);

        // Drop the middle entry; the tumor cell's slice must survive intact.
        b.retain(|a| a.kind.class_id() != 3);
        assert_eq!(b.len(), 2);
        assert_eq!(b.behaviors(0).len(), 0);
        assert_eq!(b.behaviors(1), &tumor_cell_behaviors(3.0));

        let mut c = AgentBatch::new();
        c.push(Agent::citizen(Vec3::ZERO, 2.0), &[Behavior::RandomWalk { speed: 0.5 }]);
        b.append(&mut c);
        assert_eq!(b.len(), 3);
        assert!(c.is_empty());
        assert_eq!(b.behaviors(2), &[Behavior::RandomWalk { speed: 0.5 }]);
        for (i, (a, bs)) in b.iter().enumerate() {
            assert_eq!(bs.len(), b.behaviors(i).len());
            assert_eq!(a.position, b.agents[i].position);
        }
    }
}
